"""Portfolio co-design: config validation/round-trip, one-hot parity with the
standalone single-workload search (the acceptance contract), the weighted
objective math, Pareto-front sanity, and the service integration (portfolio
requests + store_max_entries pruning)."""

import dataclasses
import math

import numpy as np
import pytest

from repro.core import (CodesignConfig, CodesignEngine, EngineConfig,
                        HWSearchConfig, ServiceConfig, SWSearchConfig)
from repro.service import CodesignService, DesignStore, ServiceRequest
from repro.timeloop import MODEL_LAYERS
from repro.workloads import (PortfolioConfig, PortfolioSession,
                             make_portfolio_engine, portfolio_codesign,
                             portfolio_session)


def tiny_config(seed=0, prune="off") -> CodesignConfig:
    return CodesignConfig(
        sw=SWSearchConfig(n_trials=10, n_warmup=5, pool_size=15),
        hw=HWSearchConfig(n_trials=3, n_warmup=2, pool_size=12, prune=prune),
        engine=EngineConfig(backend="numpy"),
        seed=seed,
    )


# --- PortfolioConfig ------------------------------------------------------------

def test_portfolio_config_roundtrip():
    pf = PortfolioConfig(workloads=("dqn", "qwen3_14b"), weights=(2.0, 1.0))
    assert PortfolioConfig.from_json(pf.to_json()) == pf
    assert PortfolioConfig.from_dict(pf.to_dict()) == pf
    # uniform default weights
    uni = PortfolioConfig(workloads=("dqn", "mlp"))
    assert uni.normalized_weights() == (0.5, 0.5)
    assert pf.normalized_weights() == (2 / 3, 1 / 3)


def test_portfolio_config_validation():
    with pytest.raises(ValueError, match="at least one workload"):
        PortfolioConfig(workloads=())
    with pytest.raises(ValueError, match="duplicate"):
        PortfolioConfig(workloads=("dqn", "dqn"))
    with pytest.raises(ValueError) as ei:
        PortfolioConfig(workloads=("dqn", "nope"))
    assert "resnet" in str(ei.value) and "qwen3_14b" in str(ei.value)
    with pytest.raises(ValueError, match="weights"):
        PortfolioConfig(workloads=("dqn", "mlp"), weights=(1.0,))
    with pytest.raises(ValueError, match="finite"):
        PortfolioConfig(workloads=("dqn",), weights=(-1.0,))
    with pytest.raises(ValueError, match="positive"):
        PortfolioConfig(workloads=("dqn", "mlp"), weights=(0.0, 0.0))
    with pytest.raises(ValueError, match="unknown portfolio keys"):
        PortfolioConfig.from_dict({"workloads": ["dqn"], "bogus": 1})


# --- engine restrictions --------------------------------------------------------

def test_portfolio_requires_prune_off():
    pf = PortfolioConfig(workloads=("dqn",))
    with pytest.raises(ValueError, match="prune"):
        make_portfolio_engine(tiny_config(prune="safe"))
    engine = CodesignEngine(tiny_config(prune="safe"))
    with pytest.raises(ValueError, match="prune"):
        PortfolioSession(engine, pf)


def test_portfolio_upgrades_sequential_strategy():
    # tiny_config resolves strategy "auto" -> "sequential" on numpy; the
    # factory upgrades it to the bit-identical layer_batched...
    engine = make_portfolio_engine(tiny_config())
    assert engine.strategy_name == "layer_batched"
    # ...and the session refuses a sequential engine outright.
    seq_cfg = dataclasses.replace(
        tiny_config(), engine=EngineConfig(backend="numpy",
                                           strategy="sequential"))
    with pytest.raises(ValueError, match="sequential"):
        PortfolioSession(CodesignEngine(seq_cfg),
                         PortfolioConfig(workloads=("dqn",)))


# --- one-hot parity (the acceptance contract) -----------------------------------

@pytest.mark.e2e
def test_one_hot_parity_with_standalone():
    """With one-hot weights the portfolio search must find the standalone
    search's best_hw exactly (identical utility stream -> identical outer
    trajectory); per-layer EDPs are bitwise equal, the geomean objective
    equal to the standalone sum up to log/exp rounding."""
    cfg = tiny_config(seed=0)
    standalone = CodesignEngine(cfg).run(MODEL_LAYERS["dqn"])
    pf = PortfolioConfig(workloads=("dqn", "mlp"), weights=(1.0, 0.0))
    res = portfolio_codesign(pf, cfg)
    assert res.best_hw == standalone.best_hw
    for name, edp in standalone.layer_edps.items():
        assert res.layer_edps[name] == edp
    assert res.stats["portfolio_member_edps"]["dqn"] \
        == standalone.best_model_edp
    assert res.best_model_edp == pytest.approx(standalone.best_model_edp,
                                               rel=1e-12)
    # the zero-weight member is still searched and reported
    assert math.isfinite(res.stats["portfolio_member_edps"]["mlp"])


@pytest.mark.e2e
def test_weighted_objective_math_and_pareto():
    cfg = tiny_config(seed=0)
    pf = PortfolioConfig(workloads=("dqn", "mlp"), weights=(2.0, 1.0))
    res = portfolio_codesign(pf, cfg)
    edps = res.stats["portfolio_member_edps"]
    want = 10.0 ** ((2 * np.log10(edps["dqn"]) + np.log10(edps["mlp"])) / 3)
    assert res.best_model_edp == pytest.approx(want, rel=1e-12)
    assert res.stats["portfolio_weights"] == pytest.approx([2 / 3, 1 / 3])
    front = res.stats["portfolio_pareto"]
    assert len(front) >= 1
    # the winner's member vector is on the front (weighted geomean argmin is
    # never dominated), and no front point dominates another
    assert any(p["member_edps"] == edps for p in front)
    for p in front:
        for q in front:
            if p is q:
                continue
            assert not all(q["member_edps"][w] <= p["member_edps"][w]
                           for w in pf.workloads)


@pytest.mark.e2e
def test_portfolio_session_snapshot_restore():
    cfg = tiny_config(seed=0)
    pf = PortfolioConfig(workloads=("dqn", "mlp"), weights=(2.0, 1.0))
    ref = portfolio_codesign(pf, cfg)

    sess = portfolio_session(pf, cfg)
    sess.step()
    snap = sess.snapshot()
    resumed = portfolio_session(pf, cfg).restore(snap)
    while resumed.step():
        pass
    res = resumed.result()
    assert res.best_hw == ref.best_hw
    assert res.best_model_edp == ref.best_model_edp
    assert res.stats["portfolio_pareto"] == ref.stats["portfolio_pareto"]


# --- service integration --------------------------------------------------------

@pytest.mark.e2e
def test_service_portfolio_request_parity(tmp_path):
    cfg = tiny_config(seed=0)
    pf = PortfolioConfig(workloads=("dqn", "mlp"), weights=(2.0, 1.0))
    standalone = portfolio_codesign(pf, cfg)

    svc = CodesignService(ServiceConfig(store_dir=str(tmp_path / "store")))
    req = ServiceRequest.from_dict({"portfolio": pf.to_dict(),
                                    "config": cfg.to_dict(), "rid": "p0"})
    assert ServiceRequest.from_json(req.to_json()) == req
    svc.submit(req)
    resp = svc.run()["p0"]
    svc.close()
    assert resp.result.best_hw == standalone.best_hw
    assert resp.result.best_model_edp == standalone.best_model_edp
    assert resp.result.stats["portfolio_member_edps"] \
        == standalone.stats["portfolio_member_edps"]


def test_service_request_portfolio_validation():
    pf = PortfolioConfig(workloads=("dqn",))
    with pytest.raises(ValueError, match="not both"):
        ServiceRequest(layers=tuple(MODEL_LAYERS["dqn"]), portfolio=pf)
    with pytest.raises(ValueError, match="no layers"):
        ServiceRequest(layers=())
    with pytest.raises(ValueError, match="prune"):
        ServiceRequest(portfolio=pf, config=tiny_config(prune="safe"))
    with pytest.raises(ValueError, match="PortfolioConfig"):
        ServiceRequest(portfolio="dqn")
    # zoo model names resolve on the JSON layers surface
    req = ServiceRequest.from_dict({"layers": "qwen3_14b"})
    assert len(req.layers) == 5
    with pytest.raises(ValueError) as ei:
        ServiceRequest.from_dict({"layers": "nope"})
    assert "qwen3_14b" in str(ei.value) and "resnet" in str(ei.value)


@pytest.mark.e2e
def test_store_max_entries_prunes(tmp_path):
    store_dir = str(tmp_path / "store")
    sc = ServiceConfig(store_dir=store_dir, store_max_entries=4)
    svc = CodesignService(sc)
    svc.submit(ServiceRequest(layers=tuple(MODEL_LAYERS["dqn"]),
                              config=tiny_config(), rid="r0"))
    svc.run()
    svc.close()
    assert 0 < len(DesignStore(store_dir)) <= 4


def test_store_max_entries_validation():
    with pytest.raises(ValueError):
        ServiceConfig(store_max_entries=-1)
    sc = ServiceConfig(store_max_entries=7)
    assert ServiceConfig.from_dict(sc.to_dict()) == sc
