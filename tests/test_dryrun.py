"""Dry-run analysis machinery: HLO collective parsing, scan-undercount
demonstration, depth variants, analytic FLOPs sanity."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ARCH_IDS, SHAPES, get_config
from repro.models.flops import cell_bytes, cell_flops, param_count


def test_cost_analysis_counts_scan_body_once():
    """The documented XLA pitfall that motivates depth extrapolation."""
    def f_scan(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=8)
        return h

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ca = jax.jit(f_scan).lower(x, w).compile().cost_analysis()
    if isinstance(ca, (list, tuple)):  # jax < 0.5 returned a per-device list
        ca = ca[0]
    one_iter = 2 * 64 * 128 * 128
    assert abs(ca["flops"] - one_iter) / one_iter < 0.1  # body counted ONCE


def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes

    hlo = """
  %all-gather = f32[16,1024]{1,0} all-gather(%p0), channel_id=1
  %ar = bf16[8,256]{1,0} all-reduce(%p1), channel_id=2
  %rs.1 = f32[4,4]{1,0} reduce-scatter(%p2), channel_id=3
  %cp = u8[100]{0} collective-permute(%p3), channel_id=4
  %ags = f32[2,2]{1,0} all-gather-start(%p4), channel_id=5
  %agd = f32[2,2]{1,0} all-gather-done(%ags), channel_id=5
  %noise = f32[9,9]{1,0} add(%a, %b)
"""
    got = collective_bytes(hlo)
    assert got["all-gather"] == 16 * 1024 * 4 + 2 * 2 * 4  # incl. -start, not -done
    assert got["all-reduce"] == 8 * 256 * 2
    assert got["reduce-scatter"] == 4 * 4 * 4
    assert got["collective-permute"] == 100
    assert got["total"] == sum(v for k, v in got.items()
                               if k not in ("total", "ops"))


def test_depth_variant_preserves_pattern():
    from repro.launch.dryrun import _depth_variant

    cfg = get_config("llama4-maverick-400b-a17b")  # pattern period 2
    v1 = _depth_variant(cfg, 1)
    assert v1.num_layers == 2 and v1.block_pattern == cfg.block_pattern
    v2 = _depth_variant(cfg, 2)
    assert v2.num_layers == 4
    enc = _depth_variant(get_config("seamless-m4t-large-v2"), 2)
    assert enc.encoder_layers == 2 and enc.num_layers == 2


@pytest.mark.parametrize("arch,lo,hi", [
    ("smollm-360m", 0.3e9, 0.5e9),
    ("phi3-medium-14b", 12e9, 16e9),
    ("qwen3-14b", 13e9, 17e9),
    ("stablelm-12b", 11e9, 14e9),
    ("qwen2-vl-72b", 65e9, 80e9),
])
def test_param_count_plausible(arch, lo, hi):
    n = param_count(get_config(arch))
    assert lo < n < hi, (arch, n / 1e9)


def test_analytic_flops_train_matches_6nd():
    """Dense-arch training FLOPs must track 6*N*D within ~35% (attention +
    vocab overheads on top of the parameter term)."""
    cfg = get_config("qwen3-14b")
    shape = SHAPES["train_4k"]
    af = cell_flops(cfg, shape)
    n = param_count(cfg)
    tokens = shape.global_batch * shape.seq_len
    six_nd = 6.0 * n * tokens
    assert 0.9 * six_nd < af["useful"] < 1.6 * six_nd


def test_analytic_bytes_decode_dominated_by_params_and_cache():
    cfg = get_config("phi3-medium-14b")
    b = cell_bytes(cfg, SHAPES["decode_32k"], 256, 16)["bytes_per_dev"]
    params_dev = param_count(cfg) * 4 / 256
    assert b >= params_dev  # at least one full param read per step