"""The speculative outer loop (ISSUE 5): `strategy="speculative"` must be
bit-identical to `strategy="sequential"` -- same best hardware, same best
mappings, same outer BO history -- on BOTH backends, for all four seed
workloads, because speculation only moves inner-search work earlier, never
changes it.  Two properties make that exact and are covered here:

  * content-derived probe seeds (`CodesignEngine.probe_seed`): a probe's
    inner search is the same no matter when or how speculatively it runs;
  * the prefetch hook is a pure observer of the scored trial's acquisition
    ranking (no RNG consumed, argmax selection untouched).

Budgets stay inside the stacked GP's Cholesky regime (sw n_trials=14, well
under `gp._LOWRANK_MIN_ROWS=32` feasible rows -- see tests/test_layer_batch.py)
where stacked fan-out searches are bit-identical to sequential ones.

The cache-spy tests pin the speculation machinery itself: speculative hits
skip re-evaluation (no (hw, layer) pair is ever searched twice), and the
reported hit-rate matches what the spy observed.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import (CodesignConfig, CodesignEngine, EngineConfig,
                        HWSearchConfig, SWSearchConfig, score_topk)
from repro.core import nested as nested_mod
from repro.core.nested import PROBE_STRATEGIES
from repro.timeloop import MODEL_LAYERS

def spec_config(strategy="speculative", backend=None, hw_stride=1,
                spec_k=3, n_hw=5, **top) -> CodesignConfig:
    # 2 warmup probes (fan-out path) + scored trials (the speculative path);
    # sw n_trials=14 keeps every stacked GP fit in the Cholesky regime.
    return CodesignConfig(
        sw=SWSearchConfig(n_trials=14, n_warmup=6, pool_size=20),
        hw=HWSearchConfig(n_trials=n_hw, n_warmup=2, pool_size=20,
                          spec_k=spec_k),
        engine=EngineConfig(backend=backend, strategy=strategy,
                            hw_gp_refit_every=hw_stride),
        **top)


def _assert_identical(a, b):
    assert a.best_hw == b.best_hw
    assert a.best_model_edp == b.best_model_edp
    assert a.best_mappings == b.best_mappings
    assert np.array_equal(a.hw_result.history, b.hw_result.history)
    assert a.hw_result.points == b.hw_result.points
    assert a.hw_result.n_infeasible == b.hw_result.n_infeasible


# --- parity -----------------------------------------------------------------------


# The many-layer workloads are the long runs; PR CI covers dqn/mlp on both
# backends and leaves resnet/transformer to the main-branch job (-m "not
# slow" vs the full suite -- see ci.yml).
@pytest.mark.parametrize("model", [
    pytest.param("resnet", marks=pytest.mark.slow),
    "dqn",
    "mlp",
    pytest.param("transformer", marks=pytest.mark.slow),
])
@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_speculative_bit_identical_to_sequential(model, backend):
    """Speculation changes WHEN inner searches run, never WHAT the outer loop
    finds: best hw, best mappings and the full outer history are bit-equal on
    both backends for every seed workload."""
    layers = MODEL_LAYERS[model]
    results = {}
    for strategy in ("sequential", "speculative"):
        eng = CodesignEngine(spec_config(strategy, backend=backend))
        results[strategy] = eng.run(layers)
        assert eng.strategy_name == strategy
    _assert_identical(results["speculative"], results["sequential"])


@pytest.mark.parametrize("hw_stride", [2, 4])
def test_speculative_parity_in_frozen_windows(hw_stride):
    """With an outer refit stride the scored trials consume one frozen
    q-batch per window -- the regime speculation targets.  Parity must hold
    there too (numpy; window pools + elites are strategy-independent)."""
    layers = MODEL_LAYERS["dqn"]
    runs = {
        s: CodesignEngine(spec_config(s, backend="numpy", n_hw=8,
                                      hw_stride=hw_stride)).run(layers)
        for s in ("sequential", "speculative")
    }
    _assert_identical(runs["speculative"], runs["sequential"])
    assert runs["speculative"].stats["spec_hits"] > 0


def test_probe_seed_is_content_derived_and_stable():
    """Same config seed + same hardware -> same probe seed, across engines
    and evaluation orders; different config seeds or probes -> different
    streams.  (Cross-process stability comes from hashing the field values,
    pinned here against a literal.)"""
    from repro.timeloop import eyeriss_168

    hw = eyeriss_168()
    e1 = CodesignEngine(spec_config())
    e2 = CodesignEngine(spec_config(strategy="sequential"))
    assert e1.probe_seed(hw) == e2.probe_seed(hw)
    assert e1.probe_seed(hw) != CodesignEngine(
        spec_config(seed=1)).probe_seed(hw)
    other = dataclasses.replace(hw, pe_mesh_x=14, pe_mesh_y=12)
    assert e1.probe_seed(hw) != e1.probe_seed(other)
    # literal pin: a refactor that changes the derivation (and therefore
    # every search result) must be a conscious choice
    assert e1.probe_seed(hw) == 5163066922624024398


def test_frozen_window_outliving_pool_resamples():
    """A refit window longer than the pool's unobserved candidates must fall
    back to resampling, not re-evaluate masked-out points forever (pool_size
    3, stride 8: without the guard one point soaks up most of the budget)."""
    cfg = CodesignConfig(
        sw=SWSearchConfig(n_trials=8, n_warmup=4, pool_size=15),
        hw=HWSearchConfig(n_trials=14, n_warmup=2, pool_size=3, elite_k=0),
        engine=EngineConfig(backend="numpy", strategy="sequential",
                            hw_gp_refit_every=8))
    r = CodesignEngine(cfg).run(MODEL_LAYERS["dqn"])
    points = r.hw_result.points
    assert len(points) == 14
    assert len(set(points)) >= len(points) - 2  # only chance collisions


def test_score_topk_ranks_descending_argmax_first():
    u = np.array([0.3, 1.7, 1.7, -np.inf, 0.9])
    idx = score_topk(u, 3)
    assert list(idx) == [1, 2, 4]  # stable ties -> argmax is entry 0
    assert int(idx[0]) == int(np.argmax(u))
    assert list(score_topk(u, 99)) == [1, 2, 4, 0, 3]  # clamped to pool


# --- cache spy --------------------------------------------------------------------


def _spied_run(config, layers):
    """Run an engine while recording every (hw, layer) pair that is actually
    searched (fan-out and per-probe paths) and every speculative fill."""
    searched = []
    speculated = []
    probes = []
    orig_fanout = nested_mod.optimize_software_fanout
    orig_many = nested_mod.optimize_software_many
    orig_topk = PROBE_STRATEGIES["speculative"].prefetch_topk
    orig_eval = PROBE_STRATEGIES["speculative"].evaluate_probe

    def spy_fanout(items, *a, **kw):
        searched.extend(items)
        return orig_fanout(items, *a, **kw)

    def spy_many(hw, todo, *a, **kw):
        searched.extend((hw, layer) for layer in todo)
        return orig_many(hw, todo, *a, **kw)

    def spy_topk(self, engine, cands):
        before = set(engine.cache)
        orig_topk(self, engine, cands)
        speculated.append({
            "argmax": cands[0],
            "filled_hw": {hw for hw, _ in set(engine.cache) - before},
        })

    def spy_eval(self, engine, hw, seed):
        # the flag the engine's own hit accounting is about to read
        probes.append((hw, hw in engine._speculated))
        orig_eval(self, engine, hw, seed)

    nested_mod.optimize_software_fanout = spy_fanout
    nested_mod.optimize_software_many = spy_many
    PROBE_STRATEGIES["speculative"].prefetch_topk = spy_topk
    PROBE_STRATEGIES["speculative"].evaluate_probe = spy_eval
    try:
        eng = CodesignEngine(config)
        result = eng.run(layers)
    finally:
        nested_mod.optimize_software_fanout = orig_fanout
        nested_mod.optimize_software_many = orig_many
        PROBE_STRATEGIES["speculative"].prefetch_topk = orig_topk
        PROBE_STRATEGIES["speculative"].evaluate_probe = orig_eval
    return eng, result, searched, speculated, probes


def test_speculative_hits_skip_reevaluation():
    """No (hw, layer) pair is ever searched twice: a speculative fill IS the
    probe's evaluation, and consuming it later runs no new inner search.  The
    reported hit-rate matches the spy's count exactly."""
    layers = MODEL_LAYERS["mlp"]
    eng, result, searched, speculated, probes = _spied_run(
        spec_config(backend="numpy", n_hw=8, hw_stride=2, spec_k=2), layers)

    # 1. speculative hits skip re-evaluation: every searched pair is unique
    # across the whole run (warmup fan-out, speculative fills, per-probe path)
    assert len(searched) == len(set(searched))

    # 2. a probe consumed as a speculative hit was already fully cached: all
    # its layers were searched before, during a prefetch, never at eval time
    hit_probes = [hw for hw, flagged in probes if flagged]
    assert len(hit_probes) > 0  # the scenario actually exercised hits
    spec_fills = set().union(*({hw for hw in r["filled_hw"] - {r["argmax"]}}
                               for r in speculated))
    for hw in hit_probes:
        assert hw in spec_fills
        for layer in layers:
            assert (hw, layer) in set(searched)

    # 3. the reported stats match the spy's counts exactly
    stats = result.stats
    assert stats["spec_hits"] == len(hit_probes)
    assert stats["spec_evaluated"] == len(spec_fills)
    assert stats["spec_hit_rate"] == len(hit_probes) / len(spec_fills)


def test_non_speculative_strategies_report_zero_spec_stats():
    r = CodesignEngine(spec_config("layer_batched",
                                   backend="numpy")).run(MODEL_LAYERS["dqn"])
    expected = {"spec_evaluated": 0, "spec_hits": 0, "spec_hit_rate": 0.0,
                "prune_considered": 0, "prune_pruned": 0,
                "pruned_fraction": 0.0, "probes_gated": 0}
    assert {k: r.stats[k] for k in expected} == expected
    # Cache accounting (ISSUE 7) rides along: the run populated the engine
    # cache (misses) and read it back at evaluation time (hits), nothing was
    # evicted (unbounded default), and the feature-memo tallies are present.
    assert r.stats["cache_size"] > 0
    assert r.stats["cache_hits"] > 0
    assert r.stats["cache_misses"] >= r.stats["cache_size"]
    assert r.stats["cache_evictions"] == 0
    for key in ("hw_feat_hits", "hw_feat_misses",
                "sw_feat_hits", "sw_feat_misses"):
        assert r.stats[key] >= 0
