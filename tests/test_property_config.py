"""Property-based tests of the typed config API (hypothesis): JSON round-trip
over randomized valid configs, and loud `ValueError` rejection of invalid
enumerated strings and `spec_k`/`elite_k` bounds.  Module-guarded through
`hypothesis_support` (skipped whole where hypothesis is not installed)."""

import dataclasses
import json

from hypothesis_support import config_dicts, given, not_in, settings, st

from repro.core import (ACQUISITIONS, BACKENDS, PRUNE_MODES, STRATEGIES,
                        SURROGATES, CodesignConfig, EngineConfig,
                        HWSearchConfig, SWSearchConfig)

import pytest


@given(config_dicts)
@settings(max_examples=60, deadline=None)
def test_config_json_round_trip(d):
    """from_dict(to_dict(cfg)) == cfg through real JSON for every valid
    config the strategy can express -- sections and fields freely omitted."""
    cfg = CodesignConfig.from_dict(d)
    assert CodesignConfig.from_dict(json.loads(json.dumps(cfg.to_dict()))) == cfg
    assert CodesignConfig.from_json(cfg.to_json()) == cfg


@given(config_dicts)
@settings(max_examples=30, deadline=None)
def test_from_dict_applies_defaults_consistently(d):
    """Omitted fields take the dataclass defaults -- from_dict(d) equals the
    explicit constructor call with the same sections."""
    cfg = CodesignConfig.from_dict(d)
    explicit = CodesignConfig(
        sw=SWSearchConfig(**d.get("sw") or {}),
        hw=HWSearchConfig(**d.get("hw") or {}),
        engine=EngineConfig(**d.get("engine") or {}),
        **{k: v for k, v in d.items() if k in ("seed", "verbose")})
    assert cfg == explicit


@given(st.sampled_from(["acquisition", "surrogate"]),
       not_in(ACQUISITIONS + SURROGATES))
@settings(max_examples=25, deadline=None)
def test_invalid_search_enums_rejected(field, bad):
    with pytest.raises(ValueError, match=field):
        SWSearchConfig(**{field: bad})


@given(st.sampled_from(["backend", "strategy", "pallas_mode"]),
       not_in(BACKENDS + STRATEGIES + ("jnp", "pallas", "interpret")))
@settings(max_examples=25, deadline=None)
def test_invalid_engine_enums_rejected(field, bad):
    with pytest.raises(ValueError, match=field):
        EngineConfig(**{field: bad})


@given(st.one_of(st.integers(max_value=0), st.booleans(),
                 st.floats(allow_nan=False), st.text(max_size=4)))
@settings(max_examples=30, deadline=None)
def test_invalid_spec_k_rejected(bad):
    """spec_k must be a real int >= 1: zero/negative ints, bools, floats and
    strings all raise at construction."""
    with pytest.raises(ValueError, match="spec_k"):
        HWSearchConfig(spec_k=bad)


@given(st.one_of(st.integers(max_value=-1), st.booleans(),
                 st.floats(allow_nan=False)))
@settings(max_examples=20, deadline=None)
def test_invalid_elite_k_rejected(bad):
    with pytest.raises(ValueError, match="elite_k"):
        SWSearchConfig(elite_k=bad)


@given(not_in(PRUNE_MODES))
@settings(max_examples=25, deadline=None)
def test_invalid_prune_mode_rejected(bad):
    """prune must be one of PRUNE_MODES -- any other string raises loudly."""
    with pytest.raises(ValueError, match="prune"):
        HWSearchConfig(prune=bad)


@given(st.one_of(st.integers(max_value=0), st.booleans(),
                 st.floats(max_value=0.0, allow_nan=False),
                 st.just(float("nan")), st.text(max_size=4)))
@settings(max_examples=30, deadline=None)
def test_invalid_prune_margin_rejected(bad):
    """prune_margin must be a real number > 0: zero/negative, bools, NaN and
    strings all raise at construction."""
    with pytest.raises(ValueError, match="prune_margin"):
        HWSearchConfig(prune_margin=bad)


@given(st.sampled_from(PRUNE_MODES),
       st.floats(0.125, 4.0, allow_nan=False, allow_infinity=False),
       st.booleans())
@settings(max_examples=20, deadline=None)
def test_prune_and_rank1_round_trip(mode, margin, rank1):
    """The pruning + rank-1 toggles survive the JSON round-trip like every
    other field -- `run.py --config` surfaces them via from_dict."""
    cfg = CodesignConfig(hw=HWSearchConfig(prune=mode, prune_margin=margin),
                         engine=EngineConfig(gp_rank1_updates=rank1))
    back = CodesignConfig.from_json(cfg.to_json())
    assert back == cfg
    assert back.hw.prune == mode
    assert back.hw.prune_margin == margin
    assert back.engine.gp_rank1_updates == rank1


@given(st.sampled_from(["probe_fanout", "speculative"]))
@settings(max_examples=4, deadline=None)
def test_fanout_strategies_require_cache(strategy):
    with pytest.raises(ValueError, match="use_cache"):
        EngineConfig(strategy=strategy, use_cache=False)
    # with the cache on they construct fine and survive replacement round-trips
    eng = EngineConfig(strategy=strategy)
    assert dataclasses.replace(eng) == eng
