"""Executor layer (ISSUE 8): process fan-out of stacked inner searches.

The load-bearing claims:

  * worker-count invariance -- `strategy="speculative"` under
    `ExecutorConfig(kind="process")` is bit-identical to inline/sequential
    on all four golden workloads, for n_workers in {1, 2, 4} (content-derived
    probe seeds make placement a free variable);
  * chunking invariance -- splitting one stacked dispatch into per-worker
    chunks only regroups which runs share a stacked fit, so entries match
    the unsplit dispatch exactly;
  * spawn hygiene -- a fresh worker boots without jax (the fork-inheritance
    regression surface), and a numpy-backend search inside a worker never
    imports the jax evaluation engine nor flips the global x64 flag;
  * worker failures re-raise in the learner with the worker traceback.

n_workers=2 runs in the PR-CI tier ("not slow"); the 1- and 4-worker sweeps
are slow-marked like the other full parity suites.
"""

import dataclasses
import hashlib
import json

import numpy as np
import pytest

from repro.core import (CodesignConfig, CodesignEngine, EngineConfig,
                        ExecutorConfig, FanoutSearchSpec, HWSearchConfig,
                        ServiceConfig, SWSearchConfig)
from repro.parallel.executor import (InlineExecutor, ProcessExecutor,
                                     _chunk_spec, make_executor)
from repro.timeloop import MODEL_LAYERS, eyeriss_168
from test_golden import GOLDEN_PATH, MODELS, _canonical

# --- config plumbing --------------------------------------------------------------


def test_executor_config_validation():
    assert ExecutorConfig() == ExecutorConfig(kind="inline", n_workers=0,
                                              chunk_items=0)
    assert ExecutorConfig().resolve_workers() >= 1
    assert ExecutorConfig(n_workers=3).resolve_workers() == 3
    with pytest.raises(ValueError, match="kind"):
        ExecutorConfig(kind="threads")
    with pytest.raises(ValueError, match="n_workers"):
        ExecutorConfig(n_workers=-1)
    with pytest.raises(ValueError, match="n_workers"):
        ExecutorConfig(n_workers=True)
    with pytest.raises(ValueError, match="chunk_items"):
        ExecutorConfig(chunk_items=-2)


def test_executor_config_json_roundtrip():
    """The executor section rides the existing config JSON surfaces: dicts
    coerce to ExecutorConfig on the way in, round-trip equality holds."""
    eng = EngineConfig(executor=ExecutorConfig(kind="process", n_workers=2))
    cfg = CodesignConfig(engine=eng)
    assert CodesignConfig.from_json(cfg.to_json()) == cfg
    # plain-dict executor section (the JSON queue path) coerces + validates
    assert EngineConfig(executor={"kind": "process"}).executor == \
        ExecutorConfig(kind="process")
    with pytest.raises(ValueError, match="executor"):
        EngineConfig(executor={"kind": "process", "bogus": 1})
    with pytest.raises(ValueError, match="executor"):
        EngineConfig(executor=7)
    sc = ServiceConfig(executor=ExecutorConfig(kind="process", n_workers=4))
    assert ServiceConfig.from_dict(sc.to_dict()) == sc


def test_make_executor_kinds():
    assert isinstance(make_executor(), InlineExecutor)
    assert isinstance(make_executor(ExecutorConfig(kind="inline")),
                      InlineExecutor)
    ex = make_executor(ExecutorConfig(kind="process", n_workers=3))
    try:
        assert isinstance(ex, ProcessExecutor)
        assert ex.n_workers == 3  # no processes started until first submit
    finally:
        ex.close()


# --- spec + chunking --------------------------------------------------------------


def _tiny_spec(n_items: int = 3, sw=None) -> FanoutSearchSpec:
    hw = eyeriss_168()
    layers = (list(MODEL_LAYERS["dqn"]) * n_items)[:n_items]
    items = tuple((hw, layer) for layer in layers)
    cfg = CodesignConfig(engine=EngineConfig(backend="numpy"))
    engine = CodesignEngine(cfg)
    seeds = tuple(engine.probe_seed(hw) + i for i in range(n_items))
    return FanoutSearchSpec(
        items=items, seeds=seeds,
        sw=sw or SWSearchConfig(n_trials=6, n_warmup=3, pool_size=10),
        engine=cfg.engine)


def test_chunk_spec_partitions_in_item_order():
    spec = _tiny_spec(5)
    assert _chunk_spec(spec, n_workers=1, chunk_items=0) == [spec]
    chunks = _chunk_spec(spec, n_workers=2, chunk_items=0)
    assert [len(c.items) for c in chunks] == [3, 2]
    chunks = _chunk_spec(spec, n_workers=4, chunk_items=1)
    assert [len(c.items) for c in chunks] == [1] * 5
    # concatenating chunk items/seeds reproduces the original order exactly
    assert sum((list(c.items) for c in chunks), []) == list(spec.items)
    assert sum((list(c.seeds) for c in chunks), []) == list(spec.seeds)
    # chunks drop the bucketing pad (it only helps a whole stack)
    padded = dataclasses.replace(spec, pad_to=6)
    assert _chunk_spec(padded, 1, 0) == [padded]
    assert all(c.pad_to is None for c in _chunk_spec(padded, 2, 2))


def test_process_entries_match_inline_across_chunkings():
    """The same spec returns identical entries inline, split evenly across
    two workers, and split down to one item per chunk."""
    spec = _tiny_spec(4)
    want = InlineExecutor().run(spec)
    for chunk_items in (0, 1):
        ex = ProcessExecutor(n_workers=2, chunk_items=chunk_items)
        try:
            assert ex.run(spec) == want, f"chunk_items={chunk_items}"
        finally:
            ex.close()


def test_worker_error_propagates_with_traceback():
    bad = dataclasses.replace(_tiny_spec(3), seeds=(0,))  # len mismatch
    ex = ProcessExecutor(n_workers=1)
    try:
        with pytest.raises(RuntimeError, match="worker traceback"):
            ex.run(bad)
        # the pool survives a failed task and keeps serving
        assert ex.run(_tiny_spec(2)) == InlineExecutor().run(_tiny_spec(2))
    finally:
        ex.close()


# --- spawn hygiene (the no-jax satellite) -----------------------------------------


def test_spawned_worker_is_jax_free_and_numpy_path_stays_clean():
    """Regression pin for worker state hygiene: a freshly spawned worker must
    not inherit the parent's jax runtime (fork would copy it wholesale), and
    running a numpy-backend search inside the worker must neither import the
    jax evaluation-engine modules nor flip the process-global x64 flag."""
    import jax  # the parent process HAS jax loaded -- that is the hazard

    assert jax is not None
    ex = ProcessExecutor(n_workers=1)
    try:
        fresh = ex.probe()
        assert fresh["inherited_jax"] == []
        assert fresh["jax_modules"] == []  # no jax at boot, period
        assert fresh["engine_modules"] == []
        assert fresh["x64_enabled"] is False

        ex.run(_tiny_spec(2))  # numpy-backend search in the same worker
        after = ex.probe()
        assert after["inherited_jax"] == []
        # The GP/BO surrogate layer is jax-based on every backend, so jax
        # itself is now loaded -- but the numpy path must not have pulled in
        # the jax evaluation engine or mutated global x64 state.
        assert after["engine_modules"] == []
        assert after["x64_enabled"] is False
    finally:
        ex.close()

    # The fork tripwire itself: a worker that *did* inherit jax modules
    # (only possible fork-started -- spawn re-imports workers.py in-process,
    # so its PID sentinel marks boot-time jax as fresh) refuses to search.
    from repro.parallel import workers
    with pytest.raises(RuntimeError, match="fork-started"):
        workers._run_search(_tiny_spec(1), inherited_jax=["jax"])


# --- golden worker-count invariance -----------------------------------------------


def _golden_config(model: str, n_workers: int) -> CodesignConfig:
    """test_golden's exact budgets, with the speculative strategy routed
    through a process executor (the acceptance-criteria configuration)."""
    return CodesignConfig(
        sw=SWSearchConfig(n_trials=10, n_warmup=5, pool_size=15),
        hw=HWSearchConfig(n_trials=3, n_warmup=2, pool_size=12,
                          num_pes=256 if model == "transformer" else 168),
        engine=EngineConfig(backend="numpy", strategy="speculative",
                            executor=ExecutorConfig(kind="process",
                                                    n_workers=n_workers)),
        seed=0,
    )


@pytest.fixture(scope="module")
def worker_pool():
    """One shared 2-worker pool for the golden runs (spawn + import cost is
    paid once per worker, not once per test)."""
    ex = ProcessExecutor(n_workers=2)
    yield ex
    ex.close()


def _record(result) -> dict:
    return {
        "design_sha256": hashlib.sha256(
            _canonical(result).encode()).hexdigest(),
        "best_log10_edp": round(float(np.log10(result.best_model_edp)), 6),
        "n_trials": len(result.hw_result.history),
    }


@pytest.mark.e2e
@pytest.mark.parametrize("model", MODELS)
def test_process_speculative_matches_golden(model, worker_pool):
    """speculative + process executor reproduces the checked-in goldens --
    the same pins the sequential inline path is held to."""
    goldens = json.loads(GOLDEN_PATH.read_text())
    engine = CodesignEngine(_golden_config(model, n_workers=2),
                            executor=worker_pool)
    assert _record(engine.run(MODEL_LAYERS[model])) == goldens[model]


@pytest.mark.slow
@pytest.mark.e2e
@pytest.mark.parametrize("n_workers", [1, 4])
@pytest.mark.parametrize("model", MODELS)
def test_worker_count_invariance(model, n_workers):
    """n_workers in {1, 4} (2 is pinned above, inline by test_golden itself):
    every pool width reproduces the identical golden record."""
    goldens = json.loads(GOLDEN_PATH.read_text())
    engine = CodesignEngine(_golden_config(model, n_workers))
    try:
        result = engine.run(MODEL_LAYERS[model])
    finally:
        engine.close()
    assert _record(result) == goldens[model], \
        f"{model} at n_workers={n_workers}"
