"""Regression tests for the GP/JAX boundary bugs (ISSUE 2).

1. `repro.core.gp` used to run `jax.config.update("jax_enable_x64", True)` at
   import time, silently flipping the whole process to x64 (conflicting with a
   float32 Pallas engine).  x64 is now scoped to the GP computations.
2. `GPClassifier.prob_feasible` used to return a JAX array, silently promoting
   the host acquisition computation in `bo_maximize` to device arrays with a
   blocking transfer per trial.  It now returns NumPy.
3. With `noisy=False`, `GP.fit` pinned `log_tau=-6` but `_fit` still trained
   it, so the other hyperparameters were optimized against a drifting noise
   level before the pin was re-applied after the fact.  The pin is now frozen
   during the fit.
"""

import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.gp import GP, GPClassifier

SRC = str(Path(__file__).resolve().parents[1] / "src")


def test_gp_import_does_not_flip_global_x64():
    """Importing the BO core in a fresh process leaves the default dtype f32."""
    code = (
        "import repro.core.gp, repro.core, jax, jax.numpy as jnp\n"
        "assert not jax.config.jax_enable_x64\n"
        "assert jnp.asarray(1.0).dtype == jnp.float32, jnp.asarray(1.0).dtype\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    subprocess.run([sys.executable, "-c", code], check=True, env=env)


def test_gp_still_computes_in_f64_scoped():
    """The scoped x64 context still gives the Cholesky solves full precision
    without touching the process-global flag."""
    rng = np.random.default_rng(0)
    X = rng.normal(size=(16, 3))
    y = X.sum(axis=1)
    gp = GP(kind="se", noisy=False).fit(X, y)
    params, Xp, yp, mask = gp._state
    assert Xp.dtype == jnp.float64
    assert all(v.dtype == jnp.float64 for v in jax.tree.leaves(params))
    assert not jax.config.jax_enable_x64
    assert jnp.asarray(1.0).dtype == jnp.float32  # process default untouched


def test_prob_feasible_returns_numpy():
    rng = np.random.default_rng(1)
    X = rng.normal(size=(30, 2))
    clf = GPClassifier().fit(X, X[:, 0] > 0)
    p = clf.prob_feasible(X)
    assert isinstance(p, np.ndarray) and not isinstance(p, jax.Array)
    assert ((0.0 <= p) & (p <= 1.0)).all()
    # unfitted classifier too (warmup path)
    assert isinstance(GPClassifier().prob_feasible(X), np.ndarray)
    # the acquisition product therefore stays a host array
    utility = np.ones(len(X)) * p
    assert isinstance(utility, np.ndarray) and not isinstance(utility, jax.Array)


def test_prob_feasible_device_twin_matches_host():
    rng = np.random.default_rng(2)
    X = rng.normal(size=(40, 3))
    clf = GPClassifier().fit(X, X[:, 0] + X[:, 1] > 0)
    np.testing.assert_allclose(
        np.asarray(clf.prob_feasible_device(jnp.asarray(X))),
        clf.prob_feasible(X),
        atol=1e-6,
    )


def test_deterministic_gp_log_tau_stays_pinned():
    """noisy=False: log_tau comes out of the fit exactly where it was pinned,
    so the historical post-fit re-pin is a no-op (the fitted params are
    invariant to it)."""
    rng = np.random.default_rng(3)
    X = rng.uniform(-1, 1, size=(20, 4))
    y = np.sin(X[:, 0]) + X[:, 1]
    gp = GP(kind="linear", noisy=False).fit(X, y)
    assert float(gp.params["log_tau"]) == -6.0
    # re-pinning after the fact changes nothing about the posterior
    from jax.experimental import enable_x64

    mu_before, var_before = gp.posterior(X)
    with enable_x64():  # match the stored f64 dtype, as GP.fit does
        gp.params["log_tau"] = jnp.asarray(-6.0)
    mu_after, var_after = gp.posterior(X)
    np.testing.assert_array_equal(mu_before, mu_after)
    np.testing.assert_array_equal(var_before, var_after)


def test_noisy_gp_still_trains_log_tau():
    rng = np.random.default_rng(4)
    X = rng.uniform(-1, 1, size=(30, 2))
    y = X.sum(axis=1) + 0.3 * rng.normal(size=30)
    init = float(np.log(max(y.std(), 1e-3) * 0.1))
    gp = GP(kind="se", noisy=True).fit(X, y)
    assert float(gp.params["log_tau"]) != pytest.approx(init, abs=1e-6)


def test_deterministic_fit_is_reproducible():
    rng = np.random.default_rng(5)
    X = rng.normal(size=(18, 3))
    y = X @ np.array([1.0, -1.0, 0.5])
    p1 = GP(kind="linear", noisy=False).fit(X, y).params
    p2 = GP(kind="linear", noisy=False).fit(X, y).params
    for k in p1:
        np.testing.assert_array_equal(np.asarray(p1[k]), np.asarray(p2[k]))
