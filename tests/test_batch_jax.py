"""Parity of the JAX evaluation engine (`repro.timeloop.batch_jax`) against the
NumPy engine (itself pinned to the scalar reference at 1e-9), plus the
device-resident BO scoring path.

Acceptance bar: <= 1e-6 relative on EDP/energy/delay/features, *exact* on
validity masks.  The default float64 engine actually lands ~1e-12; the float32
path is checked against the looser bar it is specified to meet.
"""

import dataclasses

import numpy as np
import pytest

from repro.core.bo import bo_maximize
from repro.core.swspace import SoftwareSpace
from repro.timeloop import PAPER_WORKLOADS, evaluate, eyeriss_168
from repro.timeloop import batch as tlb
from repro.timeloop import batch_jax as jtlb
from repro.timeloop.arch import hw_is_valid, sample_hardware
from repro.timeloop.mapping import constrained_random_mapping, random_mapping

RTOL = 1e-6
KEYS = ("energy_pj", "delay_cycles", "edp")
ALL_LAYERS = sorted(PAPER_WORKLOADS)  # every seed workload


def _random_pool(hw, layer, n=120, seed=0):
    """Half naive draws (exercises invalid rows), half constraint-aware."""
    rng = np.random.default_rng(seed)
    ms = [random_mapping(rng, hw, layer) for _ in range(n // 2)]
    ms += [constrained_random_mapping(rng, hw, layer) for _ in range(n - n // 2)]
    return tlb.pack(ms)


def _assert_parity(hw, layer, mb, rtol=RTOL, **kw):
    ref = tlb.evaluate_batch(hw, mb, layer)
    out = jtlb.evaluate_batch(hw, mb, layer, **kw)
    np.testing.assert_array_equal(out["valid"], ref["valid"])  # exact masks
    v = ref["valid"]
    for key in KEYS:
        assert np.isinf(out[key][~v]).all()
        np.testing.assert_allclose(out[key][v], ref[key][v], rtol=rtol)
    feats_ref = tlb.features_batch(mb, hw, layer)
    feats = jtlb.features_batch(mb, hw, layer, **kw)
    np.testing.assert_allclose(feats, feats_ref, rtol=rtol, atol=1e-12)
    return int(v.sum())


@pytest.mark.parametrize("name", ALL_LAYERS)
def test_jax_engine_parity_all_seed_workloads(name):
    layer = PAPER_WORKLOADS[name]
    hw = eyeriss_168()
    n_valid = _assert_parity(hw, layer, _random_pool(hw, layer))
    assert n_valid > 5  # the comparison exercised real valid rows


def test_jax_engine_parity_float32():
    """The accelerator dtype meets the 1e-6 bar too; masks stay exact (every
    quantity entering a validity comparison is < 2^24)."""
    layer = PAPER_WORKLOADS["ResNet-K2"]
    hw = eyeriss_168()
    _assert_parity(hw, layer, _random_pool(hw, layer), dtype="float32")


def test_jax_engine_parity_on_random_hardware():
    """Hardware enters the jitted program as an array, so one compile serves
    every config -- check parity across sampled configs (incl. dataflow pins)."""
    layer = PAPER_WORKLOADS["DQN-K1"]
    rng = np.random.default_rng(7)
    checked = 0
    while checked < 4:
        hw = sample_hardware(rng, num_pes=168)
        if not hw_is_valid(hw)[0]:
            continue
        _assert_parity(hw, layer, _random_pool(hw, layer, n=60, seed=checked))
        checked += 1


def test_jax_engine_parity_pinned_dataflow():
    layer = PAPER_WORKLOADS["DQN-K1"]
    hw = dataclasses.replace(eyeriss_168(), df_fw=2, df_fh=2)
    base = eyeriss_168()
    rng = np.random.default_rng(3)
    ms = [random_mapping(rng, base, layer) for _ in range(60)]
    ms += [constrained_random_mapping(rng, hw, layer) for _ in range(60)]
    _assert_parity(hw, layer, tlb.pack(ms))


def test_pallas_interpret_mode_matches_jnp():
    """The Pallas kernel body (run through the interpreter on CPU) computes
    exactly what the plain-jnp fallback computes."""
    hw = eyeriss_168()
    for name in ("ResNet-K4", "Transformer-K2"):
        layer = PAPER_WORKLOADS[name]
        mb = _random_pool(hw, layer, n=48, seed=11)
        ref = jtlb.evaluate_batch(hw, mb, layer, mode="jnp")
        out = jtlb.evaluate_batch(hw, mb, layer, mode="interpret")
        np.testing.assert_array_equal(out["valid"], ref["valid"])
        v = ref["valid"]
        for key in KEYS:
            np.testing.assert_allclose(out[key][v], ref[key][v], rtol=1e-12)
        np.testing.assert_allclose(
            jtlb.features_batch(mb, hw, layer, mode="interpret"),
            jtlb.features_batch(mb, hw, layer, mode="jnp"),
            rtol=1e-12,
        )


def test_valid_batch_and_scalar_oracle():
    layer = PAPER_WORKLOADS["MLP-K2"]
    hw = eyeriss_168()
    mb = _random_pool(hw, layer, n=80, seed=5)
    ok = jtlb.valid_batch(mb, hw, layer)
    from repro.timeloop.mapping import mapping_is_valid

    for i in range(len(mb)):
        assert bool(ok[i]) == mapping_is_valid(mb[i], hw, layer)[0]


def test_forward_device_returns_device_arrays():
    import jax

    hw = eyeriss_168()
    layer = PAPER_WORKLOADS["DQN-K2"]
    space = SoftwareSpace(hw, layer, backend="jax")
    pool = space.sample_pool(np.random.default_rng(0), 20)
    feats = space.features_batch_device(pool)
    assert isinstance(feats, jax.Array)
    assert feats.shape == (20, space.feature_dim)
    np.testing.assert_allclose(
        np.asarray(feats), space.features_batch(pool), rtol=1e-12)


def test_bo_jax_backend_matches_numpy_backend_choices():
    """With the f64 engine, features are bitwise-identical to NumPy's, so the
    whole BO trajectory (device-resident scoring included) picks the same
    candidates and lands on the same best value."""
    hw = eyeriss_168()
    layer = PAPER_WORKLOADS["DQN-K2"]
    bests = {}
    for backend in ("numpy", "jax"):
        space = SoftwareSpace(hw, layer, backend=backend)
        r = bo_maximize(space, n_trials=30, n_warmup=12, pool_size=30, seed=0)
        assert len(r.history) == 30 and np.isfinite(r.best_value)
        bests[backend] = r.best_value
    assert bests["jax"] == pytest.approx(bests["numpy"], rel=1e-9)


def test_bo_maximize_backend_override_is_scoped():
    hw = eyeriss_168()
    layer = PAPER_WORKLOADS["DQN-K2"]
    space = SoftwareSpace(hw, layer, backend="numpy")
    seen = []
    r = bo_maximize(space, n_trials=12, n_warmup=6, pool_size=20, seed=1,
                    backend="jax",
                    callback=lambda t, res: seen.append(space.backend))
    assert np.isfinite(r.best_value)
    assert set(seen) == {"jax"}          # the run used the override...
    assert space.backend == "numpy"      # ...and the caller's space came back
    with pytest.raises(ValueError):
        bo_maximize(space, n_trials=2, backend="torch")


def test_acquisition_device_twins_match_host():
    """The jnp acquisitions must compute the same values as the host ones,
    or the device-resident scoring path would pick different candidates."""
    from repro.core.acquisition import make_acquisition, make_acquisition_device

    import jax.numpy as jnp
    from jax.experimental import enable_x64

    rng = np.random.default_rng(0)
    mu = rng.normal(size=50)
    var = rng.uniform(1e-8, 2.0, size=50)
    with enable_x64():  # the real device path feeds f64 posterior arrays
        mu_d, var_d = jnp.asarray(mu), jnp.asarray(var)
    for name in ("ei", "lcb"):
        host = make_acquisition(name, lam=1.3)(mu, var, 0.4)
        dev = make_acquisition_device(name, lam=1.3)(mu_d, var_d, 0.4)
        # atol floors the deep-tail EI values (erf implementations differ in
        # the last ulps there); anything below 1e-10 never decides an argmax.
        np.testing.assert_allclose(np.asarray(dev), host, rtol=1e-7, atol=1e-10)


def test_empty_and_tiny_pools():
    hw = eyeriss_168()
    layer = PAPER_WORKLOADS["DQN-K2"]
    ev = jtlb.evaluate_batch(hw, tlb.pack([]), layer)
    assert ev["valid"].shape == (0,)
    mb = _random_pool(hw, layer, n=1, seed=0)
    ev = jtlb.evaluate_batch(hw, mb, layer)
    assert ev["valid"].shape == (1,)
