"""Property-based tests (hypothesis) over the system's invariants.
Module-guarded through `hypothesis_support` (skipped whole where hypothesis
is not installed)."""

import numpy as np

from hypothesis_support import given, settings, st

from repro.timeloop import HardwareConfig, PAPER_WORKLOADS, evaluate, eyeriss_168
from repro.timeloop.arch import hw_is_valid, sample_hardware
from repro.timeloop.mapping import (LEVELS, constrained_random_mapping,
                                    mapping_is_valid, random_mapping)
from repro.timeloop.workloads import DIMS, divisors, factorize
from repro.kernels.tiled_matmul import block_is_valid, vmem_bytes


@given(st.integers(1, 10_000))
@settings(max_examples=60, deadline=None)
def test_divisors_correct(n):
    ds = divisors(n)
    assert list(ds) == sorted(set(ds))
    assert all(n % d == 0 for d in ds)
    assert 1 in ds and n in ds
    # divisor count cross-check via factorization
    count = 1
    for p in set(factorize(n)):
        count *= factorize(n).count(p) + 1
    assert len(ds) == count


@given(st.integers(0, 2**32 - 1), st.sampled_from(sorted(PAPER_WORKLOADS)))
@settings(max_examples=40, deadline=None)
def test_mapping_factorization_invariant(seed, layer_name):
    """Every sampled mapping factorizes each dim exactly (S1-S6 product rule),
    for both the naive and the constraint-aware sampler."""
    layer = PAPER_WORKLOADS[layer_name]
    hw = eyeriss_168()
    rng = np.random.default_rng(seed)
    for sampler in (random_mapping, constrained_random_mapping):
        m = sampler(rng, hw, layer)
        for di, d in enumerate(DIMS):
            prod = 1
            for li in range(len(LEVELS)):
                prod *= m.factors[li][di]
            assert prod == layer.dim(d)
        assert sorted(m.order_lb) == sorted(DIMS)


@given(st.integers(0, 2**32 - 1))
@settings(max_examples=30, deadline=None)
def test_valid_mapping_has_finite_positive_edp(seed):
    layer = PAPER_WORKLOADS["DQN-K2"]
    hw = eyeriss_168()
    rng = np.random.default_rng(seed)
    m = constrained_random_mapping(rng, hw, layer)
    ok, _ = mapping_is_valid(m, hw, layer)
    ev = evaluate(hw, m, layer)
    assert ev.valid == ok
    if ok:
        assert np.isfinite(ev.edp) and ev.edp > 0
        assert ev.breakdown["used_pes"] <= hw.num_pes
        # energy >= pure compute energy; delay >= perfectly parallel compute
        assert ev.energy_pj >= layer.macs * hw.energy.mac
        assert ev.delay_cycles >= layer.macs / hw.num_pes


@given(st.integers(0, 2**32 - 1))
@settings(max_examples=30, deadline=None)
def test_sampled_hardware_structural_invariants(seed):
    rng = np.random.default_rng(seed)
    hw = sample_hardware(rng, num_pes=168)
    assert hw.pe_mesh_x * hw.pe_mesh_y == 168
    assert hw.gb_mesh_x * hw.gb_mesh_y == hw.gb_instances
    ok, why = hw_is_valid(hw)
    if ok:
        assert hw.lb_input + hw.lb_weight + hw.lb_output <= hw.lb_budget


@given(st.integers(0, 2**32 - 1), st.sampled_from(sorted(PAPER_WORKLOADS)))
@settings(max_examples=15, deadline=None)
def test_batched_engine_matches_scalar(seed, layer_name):
    """The packed-array engine agrees with the scalar reference on random
    (possibly invalid) mappings: validity bit and EDP to 1e-9 relative."""
    from repro.timeloop import batch as tlb

    layer = PAPER_WORKLOADS[layer_name]
    hw = eyeriss_168()
    rng = np.random.default_rng(seed)
    ms = [random_mapping(rng, hw, layer) for _ in range(8)]
    ev = tlb.evaluate_batch(hw, tlb.pack(ms), layer)
    for i, m in enumerate(ms):
        ref = evaluate(hw, m, layer)
        assert bool(ev["valid"][i]) == ref.valid
        if ref.valid:
            assert abs(ev["edp"][i] - ref.edp) <= 1e-9 * ref.edp


@given(st.sampled_from([128, 256, 512, 1024]),
       st.sampled_from([128, 256, 512, 1024]),
       st.sampled_from([128, 256, 512]))
@settings(max_examples=30, deadline=None)
def test_kernel_block_constraints(bm, bk, bn):
    ok, why = block_is_valid(2048, 2048, 2048, bm, bk, bn)
    if ok:
        assert vmem_bytes(bm, bk, bn) <= 96 * 2**20
        assert 2048 % bm == 0 and 2048 % bk == 0 and 2048 % bn == 0


@given(st.integers(0, 1_000_000))
@settings(max_examples=20, deadline=None)
def test_data_pipeline_deterministic(step):
    from repro.configs.base import ShapeConfig, get_smoke_config
    from repro.data.pipeline import DataConfig, SyntheticSource

    cfg = get_smoke_config("smollm-360m")
    shape = ShapeConfig("t", 32, 4, "train")
    s1 = SyntheticSource(cfg, shape, DataConfig(seed=7))
    s2 = SyntheticSource(cfg, shape, DataConfig(seed=7))
    b1, b2 = s1.batch(step), s2.batch(step)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert np.array_equal(b1["labels"], b2["labels"])
    # labels are tokens shifted by one
    assert np.array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])

@given(st.integers(0, 2**32 - 1), st.sampled_from(sorted(PAPER_WORKLOADS)),
       st.sampled_from([168, 256]))
@settings(max_examples=40, deadline=None)
def test_edp_lower_bound_sound_and_vectorized_parity(seed, layer_name, num_pes):
    """ISSUE 6 bound-and-prune contract, randomized: on a random valid
    (hardware, mapping) pair the EDP lower bound never exceeds the true
    evaluated EDP, and the vectorized twins (NumPy batch and the jitted JAX
    dispatch) agree with the scalar reference on that same (hw, layer)."""
    from repro.timeloop.batch import edp_lower_bounds_batch
    from repro.timeloop.batch_jax import edp_lower_bounds_device
    from repro.timeloop.bounds import (hw_bound_vecs, layer_bound_vecs,
                                       layer_caps, lower_bound)

    layer = PAPER_WORKLOADS[layer_name]
    rng = np.random.default_rng(seed)
    hw = sample_hardware(rng, num_pes=num_pes)
    if not hw_is_valid(hw)[0]:
        return  # structurally invalid draw: nothing to bound
    lb = lower_bound(hw, layer)
    assert np.isfinite(lb) and lb > 0
    # both vectorized backends reproduce the scalar bound
    vec = edp_lower_bounds_batch(hw_bound_vecs([hw]), layer_bound_vecs([layer]),
                                 layer_caps([layer]))[0, 0]
    dev = edp_lower_bounds_device([hw], [layer])[0, 0]
    assert abs(vec - lb) <= 1e-12 * lb
    assert abs(dev - lb) <= 1e-9 * lb
    # soundness against the scalar evaluator on a random valid mapping
    m = constrained_random_mapping(rng, hw, layer)
    if mapping_is_valid(m, hw, layer)[0]:
        ev = evaluate(hw, m, layer)
        assert lb <= ev.edp * (1 + 1e-12)
