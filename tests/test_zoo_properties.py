"""Hypothesis properties for the zoo generator and the sampler divisor guard
(skipped whole where hypothesis is absent -- see hypothesis_support)."""

from hypothesis_support import given, settings, st

from repro.timeloop import SAMPLER_DIVISOR_CAP, divisors, sampler_divisors
from repro.timeloop.workloads import _TOKENS
from repro.workloads import ZOO_NAMES, zoo_workload


@given(st.integers(1, 10_000_000))
@settings(max_examples=200, deadline=None)
def test_sampler_divisors_invariants(n):
    """The sampler ladder is always a sorted, capped, 1-and-n-containing
    subset of the true divisors -- and exactly the divisors below the cap."""
    full = divisors(n)
    ladder = sampler_divisors(n)
    assert list(ladder) == sorted(set(ladder))
    assert set(ladder) <= set(full)
    assert ladder[0] == 1 and ladder[-1] == n
    assert all(n % f == 0 for f in ladder)
    if len(full) <= SAMPLER_DIVISOR_CAP:
        assert ladder == full
    else:
        assert len(ladder) <= SAMPLER_DIVISOR_CAP


@given(st.sampled_from(ZOO_NAMES))
@settings(max_examples=20, deadline=None)
def test_zoo_layer_invariants(name):
    """Stride/extent/divisor sanity for every generated layer: positive dims,
    stride 1, consistent MACs, halo extent >= output extent, and a sampler
    ladder that is never capped (zoo dims sit under SAMPLER_DIVISOR_CAP)."""
    zw = zoo_workload(name)
    assert sum(c * l.macs for c, l in zip(zw.counts, zw.layers)) \
        == zw.total_macs
    for layer in zw.layers:
        dims = [layer.dim(d) for d in ("R", "S", "P", "Q", "C", "K")]
        assert all(d >= 1 for d in dims)
        assert layer.stride == 1
        r, s, p, q, c, k = (layer.R, layer.S, layer.P, layer.Q, layer.C,
                            layer.K)
        assert layer.macs == r * s * p * q * c * k
        assert layer.input_extent(p, r) == (p - 1) * layer.stride + r >= p
        assert layer.input_extent(q, s) >= q
        assert layer.input_size \
            == layer.input_extent(p, r) * layer.input_extent(q, s) * c
        assert layer.weight_size == r * s * c * k
        assert layer.output_size == p * q * k
        assert p <= _TOKENS
        for d in dims:
            assert sampler_divisors(d) == divisors(d)  # under the cap: exact
        assert layer.divisors("K") == list(divisors(k))
