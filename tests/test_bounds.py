"""EDP lower bounds (ISSUE 6, the bound-and-prune pass): soundness of
`bounds.lower_bound` against the scalar evaluator over random valid mappings,
and three-way parity of the scalar reference vs the vectorized twins
(`batch.edp_lower_bounds_batch` on NumPy, `batch_jax.edp_lower_bounds_device`
as one jitted dispatch).  The hypothesis-randomized soundness property lives
in tests/test_property.py (module-guarded); this module is the always-on
tier-1 cover with a fixed seeded corpus.
"""

import numpy as np
import pytest

from repro.timeloop import MODEL_LAYERS, evaluate, eyeriss_168
from repro.timeloop.arch import hw_is_valid, sample_hardware
from repro.timeloop.batch import edp_lower_bounds_batch
from repro.timeloop.batch_jax import edp_lower_bounds_device
from repro.timeloop.bounds import (_touched, edp_lower_bounds, hw_bound_vecs,
                                   layer_bound_vecs, layer_caps, lower_bound,
                                   traffic_lower_bound, used_pes_cap)
from repro.timeloop.mapping import constrained_random_mapping, mapping_is_valid

# A small mixed pool (both seed PE budgets) + every distinct seed-workload
# layer: enough shape diversity to exercise all four dataflow variants and
# both mesh families without making tier-1 slow.
_LAYERS = [layer for model in sorted(MODEL_LAYERS)
           for layer in MODEL_LAYERS[model]]


def _pool(n=12, seed=0):
    rng = np.random.default_rng(seed)
    pool = [eyeriss_168()]
    while len(pool) < n:
        hw = sample_hardware(rng, num_pes=168 if len(pool) % 2 else 256)
        if hw_is_valid(hw)[0]:
            pool.append(hw)
    return pool


def test_touched_axis_semantics():
    """touched(P, R) = distinct input positions along one axis: the halo
    extent when strides overlap, P*R disjoint windows when stride > R."""
    assert _touched(8, 1, 1) == 8          # 1x1 filter: one input per output
    assert _touched(8, 3, 1) == 10         # overlapping: (8-1)*1 + 3
    assert _touched(8, 3, 2) == 17         # stride 2, filt 3: still the halo
    assert _touched(8, 3, 4) == 24         # gapped (stride > filt): 8*3 wins
    assert _touched(1, 5, 7) == 5          # single output: the filter extent


def test_traffic_bound_at_least_naive_and_tighter_with_filters():
    """traffic_lb >= weights + outputs + P*Q*C always (each axis touches at
    least P positions), with strict improvement whenever R or S > 1."""
    for layer in _LAYERS:
        lb = traffic_lower_bound(layer)
        naive = layer.weight_size + layer.output_size + layer.P * layer.Q * layer.C
        assert lb >= naive
        if layer.R > 1 or layer.S > 1:
            assert lb > naive


def test_used_pes_cap_within_mesh():
    """The divisor-structure PE cap never exceeds the physical mesh, and is
    at least 1 (the all-temporal mapping always exists)."""
    for hw in _pool(6):
        for layer in _LAYERS[:6]:
            cap = used_pes_cap(hw, layer)
            assert 1.0 <= cap <= hw.pe_mesh_x * hw.pe_mesh_y


def test_scalar_numpy_jax_parity():
    """The three bound implementations agree: scalar reference vs the NumPy
    pool-batch vs the jitted device twin, over a mixed pool x all seed
    layers."""
    pool = _pool(12)
    ref = np.array([[lower_bound(hw, layer) for layer in _LAYERS]
                    for hw in pool])
    got_np = edp_lower_bounds_batch(
        hw_bound_vecs(pool), layer_bound_vecs(_LAYERS), layer_caps(_LAYERS))
    got_jax = edp_lower_bounds_device(pool, _LAYERS)
    assert ref.shape == got_np.shape == got_jax.shape
    np.testing.assert_allclose(got_np, ref, rtol=1e-12)
    np.testing.assert_allclose(got_jax, ref, rtol=1e-9)
    assert np.isfinite(ref).all() and (ref > 0).all()


def test_edp_lower_bounds_wrapper_matches_batch():
    pool = _pool(5, seed=3)
    layers = _LAYERS[:4]
    np.testing.assert_allclose(
        edp_lower_bounds(pool, layers),
        edp_lower_bounds_batch(hw_bound_vecs(pool), layer_bound_vecs(layers),
                               layer_caps(layers)),
        rtol=0)


def test_empty_pool_device_bounds():
    out = edp_lower_bounds_device([], _LAYERS[:2])
    assert out.shape == (0, 2)


@pytest.mark.parametrize("seed", [0, 1])
def test_bound_sound_on_random_valid_mappings(seed):
    """The contract the gate rests on: for every valid mapping m on
    (hw, layer), lower_bound(hw, layer) <= evaluate(hw, m, layer).edp.  A
    seeded corpus of constraint-aware random mappings over a mixed pool --
    any violation here would make pruning unsound, so no tolerance beyond
    f64 roundoff."""
    rng = np.random.default_rng(seed)
    checked = 0
    for hw in _pool(4, seed=seed + 10):
        for layer in _LAYERS[::3]:
            lb = lower_bound(hw, layer)
            for _ in range(6):
                m = constrained_random_mapping(rng, hw, layer)
                if not mapping_is_valid(m, hw, layer)[0]:
                    continue
                ev = evaluate(hw, m, layer)
                assert ev.valid
                assert lb <= ev.edp * (1 + 1e-12), (
                    f"bound {lb} exceeds true EDP {ev.edp} on {layer}")
                checked += 1
    assert checked > 40  # the corpus actually exercised the contract
