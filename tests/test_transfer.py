"""Cross-run transfer learning (ISSUE 10): trial-history warm starts for the
outer GP, approximate design-store hits, and the persistence/cache hardening
fixes that rode along.

The load-bearing contracts:

  * EXACTNESS -- warm starting never replays approximate results.  Prior
    rows seed only the surrogate's data (incumbent/history/budget come from
    this run's evaluations), and an approximate store hit's mapping is
    re-evaluated on the *target* hardware before it can serve.  Corollary:
    warm_start=True with an EMPTY history is bit-identical to a cold run --
    pinned here against the checked-in goldens for all four seed workloads.
  * Warm-vs-cold quality has NO universal guarantee (priors reshape the
    outer acquisition); the pinned-seed tests below document configurations
    where warm is never worse and strictly improves, exactly as recorded by
    the `transfer_e2e` benchmark.

Backend comes from REPRO_BACKEND (unset -> numpy) except the golden pins,
which force numpy like tests/test_golden.py.
"""

import dataclasses
import hashlib
import json
import os
import threading
import types
from pathlib import Path

import numpy as np
import pytest

from repro.core import (CodesignConfig, EngineConfig, HWSearchConfig,
                        LRUCache, ServiceConfig, SWSearchConfig)
from repro.core.cache import SlotCache
from repro.core.hwspace import HardwareSpace
from repro.service import (CodesignService, DesignStore, ServiceRequest,
                           TrialHistory, history_key)
from repro.timeloop import MODEL_LAYERS
from repro.timeloop.mapping import Mapping
from repro.timeloop.model import evaluate

GOLDEN_PATH = Path(__file__).parent / "goldens" / "codesign.json"


def transfer_config(seed=0, n_hw=4, warm=False, **hw_kw):
    return CodesignConfig(
        sw=SWSearchConfig(n_trials=12, n_warmup=5, pool_size=15),
        hw=HWSearchConfig(n_trials=n_hw, n_warmup=2, pool_size=15, spec_k=2,
                          warm_start=warm, **hw_kw),
        engine=EngineConfig(),
        seed=seed)


def serve_one(model, config, store_dir=None, history_dir=None):
    svc = CodesignService(ServiceConfig(store_dir=store_dir,
                                        history_dir=history_dir))
    rid = svc.submit(ServiceRequest(layers=tuple(MODEL_LAYERS[model]),
                                    config=config))
    return svc.run()[rid].result


# --- empty history is exactly a cold run -------------------------------------------


@pytest.mark.e2e
@pytest.mark.parametrize("model", ("resnet", "dqn", "mlp", "transformer"))
def test_warm_start_empty_history_matches_golden(model, tmp_path):
    """warm_start=True over an empty history must be bit-identical to cold:
    the same winning design hash and EDP the checked-in goldens pin.  (The
    golden configs force backend=numpy, so both CI jobs run one program.)"""
    cfg = CodesignConfig(
        sw=SWSearchConfig(n_trials=10, n_warmup=5, pool_size=15),
        hw=HWSearchConfig(n_trials=3, n_warmup=2, pool_size=12,
                          num_pes=256 if model == "transformer" else 168,
                          warm_start=True),
        engine=EngineConfig(backend="numpy"),
        seed=0)
    result = serve_one(model, cfg, history_dir=str(tmp_path / "history"))
    hw = dataclasses.astuple(result.best_hw)
    maps = sorted((name, dataclasses.astuple(m))
                  for name, m in result.best_mappings.items())
    got = {
        "design_sha256": hashlib.sha256(repr((hw, maps)).encode()).hexdigest(),
        "best_log10_edp": round(float(np.log10(result.best_model_edp)), 6),
        "n_trials": len(result.hw_result.history),
    }
    assert got == json.loads(GOLDEN_PATH.read_text())[model]
    assert result.stats["prior_rows"] == 0


# --- pinned warm-vs-cold quality ---------------------------------------------------


@pytest.mark.e2e
@pytest.mark.parametrize("model,seed,strict", [
    ("mlp", 0, True), ("mlp", 1, True), ("dqn", 1, True), ("mlp", 3, False),
])
def test_warm_start_not_worse_at_pinned_seeds(model, seed, strict, tmp_path):
    """At these pinned (workload, seed) points a warm-started run's incumbent
    is never worse than cold at the same outer budget -- strictly better
    where marked.  (Deterministic per backend, and these trajectories agree
    across both backends; see the module docstring for why this is a pinned
    property, not a universal one.)"""
    store, hist = str(tmp_path / "store"), str(tmp_path / "history")
    cold = serve_one(model, transfer_config(seed), store, hist)
    warm = serve_one(model, transfer_config(seed, warm=True), store, hist)
    assert warm.stats["prior_rows"] > 0
    if strict:
        assert warm.best_model_edp < cold.best_model_edp
    else:
        assert warm.best_model_edp <= cold.best_model_edp


# --- approximate store hits stay exact ---------------------------------------------


@pytest.mark.e2e
def test_approximate_hit_serves_exact_target_edp(tmp_path):
    """`nearest` returns the neighbor's OWN (mapping, edp); the transplant
    path must re-evaluate that mapping on the target hardware and serve the
    target's exact EDP -- never the neighbor's."""
    store_dir = str(tmp_path / "store")
    layers = MODEL_LAYERS["dqn"]
    serve_one("dqn", transfer_config(0), store_dir)  # populate with metadata

    store = DesignStore(store_dir)
    target = HardwareSpace().sample(np.random.default_rng(123))
    near = store.nearest(target, layers[0])
    assert near is not None
    neighbor_hw, mapping, neighbor_edp = near
    # the returned edp belongs to the neighbor's hardware...
    assert neighbor_edp == evaluate(neighbor_hw, mapping, layers[0]).edp

    # ...and the scheduler's transplant serves the target's exact evaluation
    svc = CodesignService(ServiceConfig(store_dir=store_dir))
    slot = types.SimpleNamespace(warm_hits=0)
    warm = svc._transplant(slot, (target, layers[0]))
    ev = evaluate(target, mapping, layers[0])
    if np.isfinite(ev.edp):
        assert warm == (mapping, float(ev.edp)) and slot.warm_hits == 1
        assert warm[1] != neighbor_edp or target == neighbor_hw
    else:  # mapping invalid on the target: no warm start, never a wrong EDP
        assert warm is None and slot.warm_hits == 0

    # a layer the store has never seen finds no neighbor
    other = dataclasses.replace(layers[0], C=layers[0].C + 1)
    assert store.nearest(target, other) is None


# --- trial history: round-trip, torn lines, concurrent writers ---------------------


def _row(i, feasible=True):
    return {"hw": [168, 512, 55296, 16.0, 12, 14, 192, 224, 96, 1, 1, 1, 4,
                   1, 1, 1, [0.2, 1.0, 2.0, 6.0, 200.0, float(i)]],
            "features": [float(i)] * 3,
            "utility": (-0.5 * i) if feasible else None,
            "feasible": feasible}


def test_history_append_load_roundtrip(tmp_path):
    hist = TrialHistory(str(tmp_path))
    hist.append("ab" * 16, _row(0))
    hist.append("ab" * 16, _row(1, feasible=False))
    hist.append("cd" * 16, _row(2))  # distinct key: distinct file
    rows = hist.load("ab" * 16)
    assert [r["feasible"] for r in rows] == [True, False]
    assert rows[0]["utility"] == 0.0 and rows[1]["utility"] is None
    assert rows[0]["hw"][-1] == (0.2, 1.0, 2.0, 6.0, 200.0, 0.0)  # tuples back
    assert len(hist.load("cd" * 16)) == 1
    assert hist.load("ef" * 16) == []  # unknown key: empty, not an error
    # max_rows keeps the most recent
    for i in range(5):
        hist.append("ab" * 16, _row(10 + i))
    tail = hist.load("ab" * 16, max_rows=3)
    assert [r["features"][0] for r in tail] == [12.0, 13.0, 14.0]


def test_history_skips_torn_and_foreign_lines(tmp_path):
    hist = TrialHistory(str(tmp_path))
    key = "ab" * 16
    hist.append(key, _row(0))
    path = hist._path(key)
    with open(path, "ab") as f:
        f.write(b'{"hw": [1, 2], "feat')       # torn mid-write
    hist.append(key, _row(1))
    with open(path, "ab") as f:
        f.write(b'{"foreign": true}\n')        # schema-invalid
    rows = hist.load(key)
    # the torn line glues onto the next valid one, killing both -- but never
    # the reader; every line before and after survives
    assert [r["features"][0] for r in rows] == [0.0]
    hist.append(key, _row(2))
    assert [r["features"][0] for r in hist.load(key)] == [0.0, 2.0]


def test_history_concurrent_writers(tmp_path):
    """O_APPEND single-write rows from many threads all land whole."""
    hist = TrialHistory(str(tmp_path))
    key = "ab" * 16
    n_threads, n_rows = 8, 25

    def writer(t):
        h = TrialHistory(str(tmp_path))  # own fd per writer, like processes
        for i in range(n_rows):
            h.append(key, _row(t * 1000 + i))

    threads = [threading.Thread(target=writer, args=(t,))
               for t in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    rows = hist.load(key)
    assert len(rows) == n_threads * n_rows
    seen = {int(r["features"][0]) for r in rows}
    assert seen == {t * 1000 + i for t in range(n_threads)
                    for i in range(n_rows)}


def test_history_key_invariances():
    layers = tuple(MODEL_LAYERS["dqn"])
    base = transfer_config(0)
    key = history_key(layers, base.hw, base.sw, base.engine)
    # excluded knobs: budgets, seeds-by-construction, warm_start*, spec_k
    for hw_kw in ({"n_trials": 9}, {"n_warmup": 1}, {"pool_size": 60},
                  {"spec_k": 3}, {"warm_start": True},
                  {"warm_start_rows": 7}, {"prune": "safe"}):
        alt_hw = dataclasses.replace(base.hw, **hw_kw)
        assert history_key(layers, alt_hw, base.sw, base.engine) == key
    # included: the workload set, the hw-space parameterization, the inner
    # search config, and the engine fields that determine inner results
    assert history_key(layers[:-1], base.hw, base.sw, base.engine) != key
    assert history_key(layers, dataclasses.replace(base.hw, num_pes=256),
                       base.sw, base.engine) != key
    assert history_key(layers, base.hw,
                       dataclasses.replace(base.sw, n_trials=13),
                       base.engine) != key
    other = "jax" if base.engine.resolve_backend() == "numpy" else "numpy"
    assert history_key(layers, base.hw, base.sw,
                       dataclasses.replace(base.engine, backend=other)) != key


# --- config surface ----------------------------------------------------------------


def test_warm_start_config_validation_and_roundtrip():
    for bad in ({"warm_start": "yes"}, {"warm_start_bound_mean": 1},
                {"warm_start_rows": 0}, {"warm_start_rows": -3}):
        with pytest.raises(ValueError):
            HWSearchConfig(**bad)
    with pytest.raises(ValueError):
        ServiceConfig(history_dir=7)
    cfg = transfer_config(0, warm=True, warm_start_rows=64)
    assert CodesignConfig.from_json(cfg.to_json()) == cfg
    sc = ServiceConfig(history_dir="/tmp/h")
    assert ServiceConfig.from_dict(json.loads(json.dumps(sc.to_dict()))) == sc


# --- hardening regressions (the four bugfixes) -------------------------------------


def test_store_get_malformed_entry_is_a_miss_and_evicted(tmp_path):
    """Schema-invalid (valid JSON, wrong shape) and undecodable entries are
    misses, and the poisoned file is removed so it cannot fail every future
    get."""
    store = DesignStore(str(tmp_path))
    key = "ab" * 16
    store.put(key, (None, float("inf")))
    path = store._path(key)
    for poison in (b'{"feasible": true}',       # KeyError: no mapping/edp
                   b'{"feasible": true, "mapping": 3, "edp": 1.0}',
                   b"not json at all"):
        with open(path, "wb") as f:
            f.write(poison)
        misses = store.misses
        assert store.get(key) is None
        assert store.misses == misses + 1
        assert not os.path.exists(path)
        store.put(key, (None, float("inf")))  # store stays usable
    assert store.get(key) == (None, float("inf"))


def test_slot_cache_re_put_replaces_in_place():
    """A re-put of a live key must update that slot, not append a duplicate:
    the duplicate made `get` serve the stale older slot and pushed a distinct
    live entry out of the memo."""
    a, b = object(), object()
    cache = SlotCache("test_transfer_slots", capacity=2)
    cache.put(a, 1)
    cache.put(a, 2)
    assert cache.get(a) == 2            # pre-fix: stale 1 (older slot wins)
    cache.put(b, 10)
    cache.put(a, 3)
    assert cache.get(b) == 10           # pre-fix: b evicted by a's duplicate
    assert cache.get(a) == 3
    assert len(cache._slots) == 2


def test_lru_cache_in_then_read_counts_once():
    c = LRUCache(maxsize=4)
    c["a"] = 1
    assert "a" in c and c["a"] == 1
    assert (c.hits, c.misses) == (1, 0)  # pre-fix: (2, 0)
    assert "b" not in c
    with pytest.raises(KeyError):
        c["b"]
    assert (c.hits, c.misses) == (1, 1)  # pre-fix: (1, 2)
    # any operation between the probe and the read clears the prime
    assert "a" in c
    c["x"] = 0
    assert c["a"] == 1
    assert (c.hits, c.misses) == (3, 1)
    # direct reads (no membership probe) still count normally
    assert c["x"] == 0
    assert (c.hits, c.misses) == (4, 1)


def test_store_prune_ties_break_on_path_not_size(tmp_path):
    """Equal-mtime entries evict in path order, independent of entry size.
    Pre-fix the (mtime, size, path) triple sort tie-broke on SIZE, so
    eviction order depended on how many bytes each mapping serialized to."""
    store = DesignStore(str(tmp_path))
    big = Mapping(factors=((2, 3, 5, 7, 11, 13, 17),) * 3,
                  order_lb=(0, 1, 2, 3, 4, 5, 6),
                  order_gb=(6, 5, 4, 3, 2, 1, 0),
                  order_dram=(0, 2, 4, 6, 1, 3, 5))
    keys = ["aa" + "0" * 30, "bb" + "0" * 30, "cc" + "0" * 30]
    store.put(keys[0], (big, 1.0))               # large file, path-smallest
    store.put(keys[1], (big, 2.0))               # large file
    store.put(keys[2], (None, float("inf")))     # tiny file, path-largest
    for k in keys:
        os.utime(store._path(k), (1_000_000.0, 1_000_000.0))
    assert store.prune(max_entries=1) == 2
    # path order evicts aa then bb; size order would have evicted cc first
    assert store.get(keys[2]) == (None, float("inf"))
    assert store.get(keys[0]) is None and store.get(keys[1]) is None
