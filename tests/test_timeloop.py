"""Accelerator cost-model behaviour: invariants, hand-checked micro-cases, and
the Eyeriss baseline."""

import numpy as np
import pytest

from repro.timeloop import (PAPER_WORKLOADS, HardwareConfig, Mapping, evaluate,
                            eyeriss_168, eyeriss_256, hw_is_valid)
from repro.timeloop.mapping import (LEVELS, constrained_random_mapping,
                                    mapping_is_valid, random_mapping)
from repro.timeloop.model import _level_trips, _passes
from repro.timeloop.workloads import DIMS, RELEVANCE, ConvLayer


def _mapping(factors_by_level, orders=None):
    orders = orders or {}
    f = []
    for lvl in LEVELS:
        row = [factors_by_level.get(lvl, {}).get(d, 1) for d in DIMS]
        f.append(tuple(row))
    return Mapping(
        factors=tuple(f),
        order_lb=tuple(orders.get("lb", DIMS)),
        order_gb=tuple(orders.get("gb", DIMS)),
        order_dram=tuple(orders.get("dram", DIMS)),
    )


def test_eyeriss_valid():
    for hw in (eyeriss_168(), eyeriss_256()):
        ok, why = hw_is_valid(hw)
        assert ok, why


def test_level_trips_order_sensitivity():
    # Weights are irrelevant to P; a P loop NESTED INSIDE the K loop reuses the
    # weight tile, a P loop OUTSIDE the K loop forces refetch.
    factors = {"P": 4, "K": 8}
    inside = _level_trips(("K", "P"), factors, RELEVANCE["W"])
    outside = _level_trips(("P", "K"), factors, RELEVANCE["W"])
    assert inside == 8            # only the K loop forces refetch
    assert outside == 32          # P outside K: 4 * 8


def test_output_rmw_passes():
    # C (reduction) outside P/Q/K forces output read-modify-write passes.
    factors = {"C": 4, "P": 2}
    assert _passes(("C", "P"), factors, "O") == 4
    assert _passes(("P", "C"), factors, "O") == 1
    assert _passes(("P", "C"), factors, "I") == 1


def test_evaluate_micro_case():
    """1x1 conv, all work in one PE: energy/delay computed by hand."""
    layer = ConvLayer("micro", R=1, S=1, P=2, Q=1, C=2, K=2, stride=1)
    hw = HardwareConfig(num_pes=1, pe_mesh_x=1, pe_mesh_y=1,
                        lb_input=64, lb_weight=64, lb_output=64,
                        gb_entries=1024, gb_instances=1, gb_mesh_x=1,
                        gb_mesh_y=1, gb_block=1, gb_cluster=1)
    m = _mapping({"lb": {d: layer.dim(d) for d in DIMS}})  # everything in LB
    ev = evaluate(hw, m, layer)
    assert ev.valid
    macs = 2 * 2 * 2  # P*C*K
    assert ev.breakdown["macs"] == macs
    # single fill of each tensor from DRAM through GB
    assert ev.breakdown["dram_accesses"] == layer.weight_size + layer.input_size + layer.output_size
    assert ev.breakdown["compute_cycles"] == macs
    assert ev.edp == ev.energy_pj * ev.delay_cycles


def test_invalid_mappings_rejected():
    layer = PAPER_WORKLOADS["ResNet-K1"]
    hw = eyeriss_168()
    # oversized LB tile
    m = _mapping({"lb": {"C": 64, "K": 64, "R": 3, "S": 3},
                  "dram": {"P": 56, "Q": 56}})
    ok, why = mapping_is_valid(m, hw, layer)
    assert not ok and why.startswith("lb_")


def test_more_pes_not_slower():
    """Compute cycles strictly decrease with more spatial parallelism."""
    layer = PAPER_WORKLOADS["DQN-K2"]
    hw = eyeriss_168()
    m1 = _mapping({"lb": {"R": 4, "S": 4}, "dram": {"P": 9, "Q": 9, "C": 16, "K": 32}})
    m2 = _mapping({"lb": {"R": 4, "S": 4}, "sx": {"C": 8}, "sy": {"K": 8},
                   "dram": {"P": 9, "Q": 9, "C": 2, "K": 4}})
    e1, e2 = evaluate(hw, m1, layer), evaluate(hw, m2, layer)
    assert e1.valid and e2.valid
    assert e2.breakdown["compute_cycles"] < e1.breakdown["compute_cycles"]


@pytest.mark.parametrize("name", ["ResNet-K1", "DQN-K1", "MLP-K1", "Transformer-K2"])
def test_samplers_produce_feasible(name):
    layer = PAPER_WORKLOADS[name]
    hw = eyeriss_168()
    rng = np.random.default_rng(0)
    n_ok = 0
    for _ in range(200):
        m = constrained_random_mapping(rng, hw, layer)
        for di, d in enumerate(DIMS):
            prod = 1
            for li in range(len(LEVELS)):
                prod *= m.factors[li][di]
            assert prod == layer.dim(d)
        if mapping_is_valid(m, hw, layer)[0]:
            n_ok += 1
            ev = evaluate(hw, m, layer)
            assert ev.valid and np.isfinite(ev.edp) and ev.edp > 0
    assert n_ok > 20  # constraint-aware sampler keeps a healthy feasible rate
