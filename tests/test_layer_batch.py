"""Layer-batched nested search: the multi-run BO engine (`bo_maximize_many` /
`LayerStackSpace` / `GPStack`) against the sequential per-layer path.

Parity bars (ISSUE 3):
  * NumPy fallback: same seeds => *identical* best mappings / EDPs (the
    lockstep engine reproduces L sequential `bo_maximize` runs bit-for-bit in
    the small-bucket Cholesky regime these tests run in);
  * JAX f64: <= 1e-6 relative EDP (in practice also identical here);
  * all four seed workload sets (ResNet / DQN / MLP / Transformer).

Plus units for the stacked building blocks: `forward_device_stacked` row
parity, `GPStack`/`GPClassifierStack` vs individual fits, the low-rank linear
NLL, the batched hardware-pool protocol, and the end-to-end `gp_refit_every`
threading (which also exercises the multi-cohort refit schedule).
"""

import numpy as np
import pytest

from repro.core.bo import BOResult, bo_maximize, bo_maximize_many
from repro.core.gp import GP, GPClassifier, GPClassifierStack, GPStack
from repro.core.hwspace import HardwareSpace
from repro.core.nested import codesign, optimize_software, optimize_software_many
from repro.core.swspace import LayerStackSpace, SoftwareSpace
from repro.timeloop import MODEL_LAYERS, eyeriss_168
from repro.timeloop import batch as tlb
from repro.timeloop import batch_jax as jtlb

MODELS = ("resnet", "dqn", "mlp", "transformer")
# Budgets chosen to stay inside the stacked fit's Cholesky regime
# (<= gp._LOWRANK_MIN_ROWS data rows), where lockstep == sequential exactly.
KW = dict(n_trials=14, n_warmup=6, pool_size=20, seed=3)


def _assert_run_parity(seq: BOResult, many: BOResult, backend: str):
    assert many.best_point == seq.best_point
    # Same winner => identical EDP; the histories pin the whole trajectory.
    assert np.array_equal(many.history, seq.history)
    if seq.best_point is not None:
        edp_s, edp_m = 10.0 ** -seq.best_value, 10.0 ** -many.best_value
        assert edp_m == pytest.approx(edp_s, rel=1e-6)  # ISSUE bar (jax f64)


@pytest.mark.parametrize("model", MODELS)
def test_layer_batched_matches_sequential_numpy(model):
    hw = eyeriss_168()
    layers = MODEL_LAYERS[model]
    seq = [optimize_software(hw, ly, backend="numpy", **KW) for ly in layers]
    many = optimize_software_many(hw, layers, backend="numpy", **KW)
    assert len(many) == len(layers)
    for rs, rm in zip(seq, many):
        _assert_run_parity(rs, rm, "numpy")


@pytest.mark.parametrize("model", MODELS)
def test_layer_batched_matches_sequential_jax(model):
    hw = eyeriss_168()
    layers = MODEL_LAYERS[model]
    seq = [optimize_software(hw, ly, backend="jax", **KW) for ly in layers]
    many = optimize_software_many(hw, layers, backend="jax", **KW)
    for rs, rm in zip(seq, many):
        _assert_run_parity(rs, rm, "jax")


def test_codesign_layer_batched_identical_to_sequential():
    """`codesign(layer_batched=True)` collapses eval_hw's layer loop into one
    bo_maximize_many call per probe; with the shared (hw, layer) cache the
    whole nested search must land on the same design as the sequential path."""
    layers = MODEL_LAYERS["dqn"]
    kw = dict(n_hw_trials=3, n_sw_trials=12, n_sw_warmup=6, sw_pool=20,
              hw_pool=20, seed=0, backend="numpy")
    r_seq = codesign(layers, layer_batched=False, **kw)
    r_lb = codesign(layers, layer_batched=True, **kw)
    assert r_lb.best_hw == r_seq.best_hw
    assert r_lb.best_model_edp == r_seq.best_model_edp
    assert r_lb.best_mappings == r_seq.best_mappings
    assert np.array_equal(r_lb.hw_result.history, r_seq.hw_result.history)


def test_codesign_layer_batched_defaults_by_backend():
    """layer_batched=None resolves to the backend: on for jax, off for numpy
    (the numpy default keeps the sequential path; forcing True works too)."""
    layers = MODEL_LAYERS["dqn"]
    kw = dict(n_hw_trials=2, n_sw_trials=10, n_sw_warmup=5, sw_pool=16,
              hw_pool=16, seed=1)
    r = codesign(layers, backend="jax", **kw)  # auto layer-batched
    assert r.best_hw is not None and np.isfinite(r.best_model_edp)
    r2 = codesign(layers, backend="jax", layer_batched=True, **kw)
    assert r2.best_model_edp == r.best_model_edp


# --- stacked forward ------------------------------------------------------------


def test_forward_device_stacked_matches_per_layer():
    """The (L*B,)-row fused program computes per row exactly what L separate
    forward_device calls compute; rows past a pool's length are padding."""
    hw = eyeriss_168()
    layers = [MODEL_LAYERS["resnet"][0], MODEL_LAYERS["dqn"][1],
              MODEL_LAYERS["mlp"][0], MODEL_LAYERS["transformer"][2]]
    rng = np.random.default_rng(0)
    pools = [tlb.sample_valid_pool(rng, hw, ly, 12 + 5 * i)
             for i, ly in enumerate(layers)]
    out = jtlb.forward_device_stacked(hw, pools, layers)
    B = max(len(p) for p in pools)
    assert out["features"].shape == (len(layers), B, 14)
    for k, (p, ly) in enumerate(zip(pools, layers)):
        ref = jtlb.forward_device(hw, p, ly)
        n = len(p)
        np.testing.assert_array_equal(
            np.asarray(out["valid"][k][:n]), np.asarray(ref["valid"]))
        for key in ("edp", "utility", "features"):
            np.testing.assert_allclose(
                np.asarray(out[key][k][:n]), np.asarray(ref[key]), rtol=1e-12)
        assert not np.asarray(out["valid"][k][n:]).any()


def test_forward_device_stacked_interpret_mode():
    """The Pallas-kernel path handles the stacked row count (L*bucket is not
    a power of two) by shrinking its block size."""
    hw = eyeriss_168()
    layers = MODEL_LAYERS["resnet"][:3]
    rng = np.random.default_rng(1)
    pools = [tlb.sample_valid_pool(rng, hw, ly, 10) for ly in layers]
    ref = jtlb.forward_device_stacked(hw, pools, layers, mode="jnp")
    out = jtlb.forward_device_stacked(hw, pools, layers, mode="interpret")
    np.testing.assert_array_equal(np.asarray(out["valid"]),
                                  np.asarray(ref["valid"]))
    v = np.asarray(ref["valid"])
    np.testing.assert_allclose(np.asarray(out["edp"])[v],
                               np.asarray(ref["edp"])[v], rtol=1e-12)


def test_layer_stack_space_protocol():
    hw = eyeriss_168()
    layers = MODEL_LAYERS["dqn"]
    spaces = [SoftwareSpace(hw, ly, backend="jax") for ly in layers]
    stack = LayerStackSpace.maybe(spaces)
    assert stack is not None and stack.supports_device
    rng = np.random.default_rng(0)
    pools = [s.sample_pool(rng, 8) for s in spaces]
    fwd = stack.forward_stacked(pools)
    for k, s in enumerate(spaces):
        np.testing.assert_allclose(
            fwd["features"][k], s.features_batch(pools[k]), rtol=1e-12)
        vals, feas = s.evaluate_batch(pools[k])
        np.testing.assert_array_equal(fwd["valid"][k], feas)
        np.testing.assert_allclose(fwd["utility"][k], vals, rtol=1e-12)
    # mixed-backend / non-software spaces don't stack
    assert LayerStackSpace.maybe(
        [SoftwareSpace(hw, layers[0], backend="jax"),
         SoftwareSpace(hw, layers[1], backend="numpy")]) is None
    assert LayerStackSpace.maybe([HardwareSpace()]) is None


# --- stacked GPs ----------------------------------------------------------------


def test_gp_stack_matches_individual_fits():
    """Each slice of a GPStack reproduces the corresponding individual GP fit
    (ragged run sizes share one padded bucket; padding is zero-influence)."""
    rng = np.random.default_rng(0)
    Xs = [rng.normal(size=(n, 5)) for n in (6, 13, 26)]
    ys = [X @ np.array([1.0, -2.0, 0.5, 0.0, 3.0]) + 0.05 * rng.normal(size=len(X))
          for X in Xs]
    pools = np.stack([rng.normal(size=(9, 5)) for _ in Xs])
    for kind in ("linear", "se"):
        for noisy in (False, True):
            stack = GPStack(kind=kind, noisy=noisy).fit(Xs, ys)
            mu_s, var_s = stack.posterior(pools)
            for k, (X, y) in enumerate(zip(Xs, ys)):
                mu, var = GP(kind=kind, noisy=noisy).fit(X, y).posterior(pools[k])
                np.testing.assert_allclose(mu_s[k], mu, atol=1e-8)
                np.testing.assert_allclose(var_s[k], var, atol=1e-8)


def test_gp_stack_lowrank_regime_close_to_cholesky():
    """Above the row threshold the linear-kernel stack fits through the
    Woodbury NLL; the posterior agrees with the Cholesky fit to far below
    anything an acquisition argmax can resolve at those data sizes."""
    rng = np.random.default_rng(1)
    Xs = [rng.normal(size=(n, 6)) for n in (40, 52)]   # > _LOWRANK_MIN_ROWS
    ys = [X @ rng.normal(size=6) + 0.05 * rng.normal(size=len(X)) for X in Xs]
    stack = GPStack(kind="linear", noisy=False).fit(Xs, ys)
    pools = np.stack([rng.normal(size=(7, 6)) for _ in Xs])
    mu_s, _ = stack.posterior(pools)
    for k, (X, y) in enumerate(zip(Xs, ys)):
        mu, _ = GP(kind="linear", noisy=False).fit(X, y).posterior(pools[k])
        np.testing.assert_allclose(mu_s[k], mu, atol=1e-5)


def test_gp_classifier_stack_matches_individual():
    rng = np.random.default_rng(2)
    Xs = [rng.normal(size=(n, 3)) for n in (18, 30)]
    feas = [X[:, 0] > 0 for X in Xs]
    cs = GPClassifierStack().fit(Xs, feas)
    pools = np.stack([rng.normal(size=(6, 3)) for _ in Xs])
    ps = cs.prob_feasible(pools)
    pd = np.asarray(cs.prob_feasible_device(pools))
    for k, (X, f) in enumerate(zip(Xs, feas)):
        p = GPClassifier().fit(X, f).prob_feasible(pools[k])
        np.testing.assert_allclose(ps[k], p, atol=1e-8)
        np.testing.assert_allclose(pd[k], p, atol=1e-6)


def test_lowrank_nll_matches_cholesky_nll():
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.core.gp import _init_params, _nll, _nll_linear_lowrank

    rng = np.random.default_rng(3)
    n, npad, d = 21, 32, 7
    X = np.zeros((npad, d)); y = np.zeros(npad); mask = np.zeros(npad)
    X[:n] = rng.normal(size=(n, d)); y[:n] = rng.normal(size=n); mask[:n] = 1.0
    with enable_x64():
        params = dict(_init_params("linear", d),
                      mean_const=jnp.asarray(0.4), log_tau=jnp.asarray(-6.0),
                      log_w=jnp.asarray(rng.normal(size=d) * 0.3),
                      log_bias=jnp.asarray(0.1))
        a = float(_nll(params, jnp.asarray(X), jnp.asarray(y),
                       jnp.asarray(mask), "linear"))
        b = float(_nll_linear_lowrank(params, jnp.asarray(X), jnp.asarray(y),
                                      jnp.asarray(mask)))
    assert b == pytest.approx(a, rel=1e-8)


# --- batched hardware pools -----------------------------------------------------


def test_hardware_space_batched_protocol():
    from repro.timeloop.arch import hw_is_valid

    sp = HardwareSpace(num_pes=168)
    assert sp.supports_batch
    rng = np.random.default_rng(0)
    pool = sp.sample_pool(rng, 64)
    assert len(pool) == 64
    assert all(hw_is_valid(hw)[0] for hw in pool)
    feats = sp.features_batch(pool)
    ref = np.stack([sp.features(hw) for hw in pool])
    np.testing.assert_array_equal(feats, ref)  # bitwise twin of the scalar path


def test_hardware_space_bo_takes_batched_path():
    """The outer BO loop runs the hardware space through the batched protocol
    end-to-end (warmup pool + per-trial acquisition pools) with a synthetic
    evaluator, including unknown-constraint (infeasible) outcomes."""
    def eval_fn(hw):
        if hw.df_fw == 2:  # synthetic unknown constraint
            return None, False
        return -float(np.log10(hw.lb_input + 2.0 * hw.lb_output)), True

    sp = HardwareSpace(num_pes=168, evaluate_fn=eval_fn)
    r = bo_maximize(sp, n_trials=14, n_warmup=6, pool_size=16, noisy=True,
                    seed=0)
    assert len(r.history) == 14
    assert np.isfinite(r.best_value)
    assert r.n_infeasible > 0  # classifier path exercised


# --- gp_refit_every threading + multi-cohort schedule ---------------------------


def test_gp_refit_every_parity_and_threading():
    """The amortization stride is reachable end-to-end and the lockstep
    engine's cohort schedule reproduces the sequential per-run refit schedule
    (runs whose surrogate first fits off-schedule form their own cohort)."""
    hw = eyeriss_168()
    layers = MODEL_LAYERS["mlp"]
    kw = dict(n_trials=14, n_warmup=6, pool_size=20, seed=5,
              gp_refit_every=4, backend="numpy")
    seq = [optimize_software(hw, ly, **kw) for ly in layers]
    many = optimize_software_many(hw, layers, **kw)
    for rs, rm in zip(seq, many):
        assert rm.best_point == rs.best_point
        assert np.array_equal(rm.history, rs.history)
    r = codesign(MODEL_LAYERS["dqn"], n_hw_trials=2, n_sw_trials=10,
                 n_sw_warmup=5, sw_pool=16, hw_pool=16, seed=0,
                 gp_refit_every=3, backend="numpy")
    assert np.isfinite(r.best_model_edp)


# --- engine fallbacks / early-stop ----------------------------------------------


class _BatchQuad:
    """Minimal batched-protocol space: maximize -(x-c)^2 over [-1, 1]^3."""

    name = "quad"
    feature_dim = 3
    supports_batch = True

    def __init__(self, c, fail=False):
        self.c = np.asarray(c, dtype=np.float64)
        self.fail = fail

    def sample(self, rng):
        return rng.uniform(-1, 1, 3)

    def is_valid(self, x):
        return True

    def features(self, x):
        return np.asarray(x, dtype=np.float64)

    def evaluate(self, x):
        return -float(np.sum((np.asarray(x) - self.c) ** 2)), True

    def sample_pool(self, rng, n):
        if self.fail:
            return None
        return [self.sample(rng) for _ in range(n)]

    def features_batch(self, pool):
        return np.asarray(pool, dtype=np.float64)

    def evaluate_batch(self, pool):
        vals = -np.sum((np.asarray(pool) - self.c) ** 2, axis=1)
        return vals, np.ones(len(pool), dtype=bool)


def test_bo_maximize_many_generic_spaces_match_sequential():
    """Spaces that don't stack (not SoftwareSpace) still advance in lockstep
    through per-space batched calls, matching sequential runs exactly."""
    cs = ([0.3, -0.2, 0.5], [-0.4, 0.1, 0.0], [0.0, 0.6, -0.3])
    seq = [bo_maximize(_BatchQuad(c), n_trials=16, n_warmup=6, pool_size=24,
                       seed=7) for c in cs]
    many = bo_maximize_many([_BatchQuad(c) for c in cs], n_trials=16,
                            n_warmup=6, pool_size=24, seed=7)
    for rs, rm in zip(seq, many):
        assert np.array_equal(rm.best_point, rs.best_point)
        assert np.array_equal(rm.history, rs.history)


def test_bo_maximize_many_early_stop_mask():
    """A run whose space is unsampleable finishes early with an empty result;
    the other runs are unaffected."""
    good, bad = _BatchQuad([0.2, 0.2, 0.2]), _BatchQuad([0.0] * 3, fail=True)
    ref = bo_maximize_many([good], n_trials=12, n_warmup=5, pool_size=16, seed=1)
    many = bo_maximize_many([_BatchQuad([0.2, 0.2, 0.2]), bad],
                            n_trials=12, n_warmup=5, pool_size=16, seed=1)
    assert many[1].best_point is None and many[1].history == []
    assert np.array_equal(many[0].history, ref[0].history)


def test_bo_maximize_many_fallbacks():
    sp = _BatchQuad([0.1, 0.1, 0.1])
    assert bo_maximize_many([], n_trials=4) == []
    (single,) = bo_maximize_many([sp], n_trials=10, n_warmup=4, pool_size=12,
                                 seed=2)
    ref = bo_maximize(_BatchQuad([0.1, 0.1, 0.1]), n_trials=10, n_warmup=4,
                      pool_size=12, seed=2)
    assert np.array_equal(single.history, ref.history)
    rf = bo_maximize_many([_BatchQuad([0.1] * 3), _BatchQuad([0.2] * 3)],
                          n_trials=10, n_warmup=4, pool_size=12, seed=2,
                          surrogate="rf")
    assert all(np.isfinite(r.best_value) for r in rf)
