"""Substrate tests: optimizer, data pipeline, checkpointing, fault tolerance."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig, get_smoke_config
from repro.checkpoint import checkpoint as ckpt
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticSource
from repro.optim import adamw
from repro.runtime.fault_tolerance import (InjectedFault, ResilientLoop,
                                           StragglerMonitor)


def test_adamw_decreases_quadratic():
    cfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=1,
                            total_steps=200, clip_norm=100.0)
    params = {"w": jnp.array([3.0, -2.0, 1.0])}
    state = adamw.init_state(cfg, params)

    def loss_fn(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(150):
        g = jax.grad(loss_fn)(params)
        params, state, _ = adamw.apply_updates(cfg, params, state, g)
    assert float(loss_fn(params)) < 1e-2


def test_grad_clip_norm():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(200.0)
    assert float(adamw.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_int8_grad_compression_bounded_error():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(128,)), jnp.float32)}
    deq = adamw.decompress_int8(adamw.compress_int8(g))
    err = float(jnp.max(jnp.abs(deq["w"] - g["w"])))
    assert err <= float(jnp.max(jnp.abs(g["w"]))) / 127 + 1e-6


def test_schedule_warmup_and_decay():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(adamw.schedule(cfg, jnp.asarray(5))) == pytest.approx(0.5)
    assert float(adamw.schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0, rel=1e-2)
    assert float(adamw.schedule(cfg, jnp.asarray(100))) == pytest.approx(cfg.min_lr_frac, rel=1e-2)


def test_prefetcher_matches_direct():
    cfg = get_smoke_config("smollm-360m")
    shape = ShapeConfig("t", 16, 4, "train")
    src = SyntheticSource(cfg, shape, DataConfig(seed=1))
    pf = Prefetcher(src, start_step=0)
    try:
        for want in range(3):
            step, batch = next(pf)
            assert step == want
            direct = src.batch(step)
            assert np.array_equal(batch["tokens"], direct["tokens"])
    finally:
        pf.close()


def test_checkpoint_roundtrip(tmp_path):
    state = {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
             "opt": {"step": jnp.asarray(7, jnp.int32)}}
    ckpt.save(str(tmp_path), 7, state)
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
    restored, step = ckpt.restore(str(tmp_path), like)
    assert step == 7
    assert np.array_equal(restored["params"]["w"], np.asarray(state["params"]["w"]))
    assert ckpt.latest_step(str(tmp_path)) == 7


def test_checkpoint_latest_pointer_advances(tmp_path):
    state = {"x": jnp.zeros(3)}
    ckpt.save(str(tmp_path), 1, state)
    ckpt.save(str(tmp_path), 2, state)
    assert ckpt.latest_step(str(tmp_path)) == 2


class _CountingSource:
    def __init__(self):
        self.calls = []

    def batch(self, step):
        self.calls.append(step)
        return {"step": step}


def test_resilient_loop_restarts_and_replays(tmp_path):
    """Injected faults must restore from the latest checkpoint and replay the
    exact same data steps (determinism contract)."""
    src = _CountingSource()
    trace = []

    def step_fn(state, batch):
        trace.append(batch["step"])
        return state + 1, {"loss": 0.0}

    loop = ResilientLoop(step_fn, src, str(tmp_path), save_every=4)
    state, step, mlog, monitor = loop.run(
        jnp.asarray(0), 0, 12, fault_schedule={6, 9})
    assert step == 12
    # state was rolled back on each restart, so it counts only the steps on
    # the surviving path: exactly 12
    assert int(state) == 12
    assert len(trace) > 12                    # replays actually executed
    assert trace.count(4) >= 2 or trace.count(8) >= 2  # same data replayed


def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(z_threshold=3.0)
    for _ in range(20):
        mon.observe(0.1 + np.random.default_rng(0).normal() * 0)
    assert bool(mon.observe(10.0))
    assert mon.flagged == 1


@pytest.mark.skipif(not hasattr(jax.sharding, "AxisType"),
                    reason="jax.sharding.AxisType requires jax >= 0.5")
def test_sharding_filter_spec():
    from jax.sharding import PartitionSpec as P
    from repro.parallel.sharding import _filter_spec
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    spec = _filter_spec(mesh, (("pod", "data"), None, "model"))
    assert spec == P(("data",), None, None)


@pytest.mark.skipif(not hasattr(jax.sharding, "AxisType"),
                    reason="jax.sharding.AxisType requires jax >= 0.5")
def test_param_spec_roles():
    from repro.parallel.sharding import AxisRules, param_spec
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    rules = AxisRules()
    spec = param_spec("blocks/pos0/mlp/wi_mlp_up", (4, 64, 256), mesh, rules)
    assert spec[2] == "model" and spec[1] == "data"  # ff + fsdp
    spec = param_spec("embed/embedding", (512, 64), mesh, rules, stacked=False)
    assert spec[0] == "model"                         # vocab
    spec = param_spec("blocks/pos0/moe/expert_wi", (4, 8, 64, 128), mesh, rules)
    assert spec[1] == "model"                         # expert axis