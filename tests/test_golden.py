"""Seeded golden end-to-end regression tests (ISSUE 5): tiny-budget `codesign`
runs per seed workload, pinned against checked-in goldens, so cross-PR result
drift fails tier-1 instead of surfacing only through the benchmark gate.

Each golden is (a) a content hash of the winning design -- the best hardware
config and every layer's best mapping -- and (b) the best model log10(EDP)
rounded to 6 decimals.  The search is forced onto backend="numpy" so both CI
backends (REPRO_BACKEND=numpy and =jax) run the identical program; the GP
surrogate still runs through JAX, so a jax version bump that flips an argmax
would surface here -- that is drift worth seeing, and regenerating is one
command:

    PYTHONPATH=src python tests/test_golden.py --regen

which rewrites tests/goldens/codesign.json (commit the diff ONLY when the
change is an intended search-behavior change).
"""

import dataclasses
import hashlib
import json
from pathlib import Path

import numpy as np
import pytest

from repro.core import (CodesignConfig, CodesignEngine, EngineConfig,
                        HWSearchConfig, SWSearchConfig)
from repro.timeloop import MODEL_LAYERS

GOLDEN_PATH = Path(__file__).parent / "goldens" / "codesign.json"
MODELS = ("resnet", "dqn", "mlp", "transformer")


def _config(model: str) -> CodesignConfig:
    """Tiny deterministic budgets: seconds per workload, but a real nested
    search (warmup + scored trials, surrogate + acquisition + cache)."""
    return CodesignConfig(
        sw=SWSearchConfig(n_trials=10, n_warmup=5, pool_size=15),
        hw=HWSearchConfig(n_trials=3, n_warmup=2, pool_size=12,
                          num_pes=256 if model == "transformer" else 168),
        engine=EngineConfig(backend="numpy"),  # identical under both CI jobs
        seed=0,
    )


def _canonical(result) -> str:
    """Deterministic text form of the winning design: hardware fields plus
    each layer's mapping fields, all plain ints/floats/strings."""
    hw = dataclasses.astuple(result.best_hw)
    maps = sorted(
        (name, dataclasses.astuple(m)) for name, m in result.best_mappings.items())
    return repr((hw, maps))


def run_one(model: str) -> dict:
    result = CodesignEngine(_config(model)).run(MODEL_LAYERS[model])
    return {
        "design_sha256": hashlib.sha256(_canonical(result).encode()).hexdigest(),
        "best_log10_edp": round(float(np.log10(result.best_model_edp)), 6),
        "n_trials": len(result.hw_result.history),
    }


@pytest.mark.e2e
@pytest.mark.parametrize("model", MODELS)
def test_codesign_matches_golden(model):
    goldens = json.loads(GOLDEN_PATH.read_text())
    got = run_one(model)
    want = goldens[model]
    assert got == want, (
        f"golden e2e drift on {model!r}:\n  got  {got}\n  want {want}\n"
        "If this PR intentionally changes search behavior, regenerate with\n"
        "  PYTHONPATH=src python tests/test_golden.py --regen\n"
        "and commit the goldens diff; otherwise this is a regression.")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--regen", action="store_true",
                    help="rewrite tests/goldens/codesign.json")
    args = ap.parse_args()
    records = {m: run_one(m) for m in MODELS}
    print(json.dumps(records, indent=2))
    if args.regen:
        GOLDEN_PATH.parent.mkdir(parents=True, exist_ok=True)
        GOLDEN_PATH.write_text(json.dumps(records, indent=2, sort_keys=True) + "\n")
        print(f"wrote {GOLDEN_PATH}")
