"""Pallas kernels vs pure-jnp oracles: shape/dtype/block sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import flash_attention_ref, matmul_ref
from repro.kernels.tiled_matmul import tiled_matmul

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-4), (jnp.bfloat16, 2e-1)])
@pytest.mark.parametrize("m,k,n,bm,bk,bn", [
    (128, 256, 128, 32, 64, 64),
    (256, 128, 384, 64, 128, 128),
    (64, 512, 256, 8, 256, 128),
    (128, 128, 128, 128, 128, 128),   # single block
])
def test_tiled_matmul_sweep(m, k, n, bm, bk, bn, dtype, tol):
    x = jnp.asarray(RNG.normal(size=(m, k)), dtype)
    w = jnp.asarray(RNG.normal(size=(k, n)), dtype)
    got = tiled_matmul(x, w, bm=bm, bk=bk, bn=bn, interpret=True)
    ref = matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol * k ** 0.5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-4), (jnp.bfloat16, 5e-2)])
@pytest.mark.parametrize("B,S,H,KV,hd,bq,bk", [
    (2, 64, 4, 2, 16, 16, 16),
    (1, 128, 8, 2, 32, 32, 64),
    (2, 64, 4, 4, 8, 64, 32),      # MHA (g=1)
    (1, 128, 4, 1, 64, 128, 128),  # MQA, single block pair
])
def test_flash_attention_sweep(B, S, H, KV, hd, bq, bk, dtype, tol):
    q = jnp.asarray(RNG.normal(size=(B, S, H, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, S, KV, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, S, KV, hd)), dtype)
    got = flash_attention(q, k, v, bq=bq, bk=bk, interpret=True)
    ref = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_ops_dispatch_cpu_interpret():
    from repro.kernels import ops
    x = jnp.asarray(RNG.normal(size=(64, 128)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(128, 128)), jnp.float32)
    np.testing.assert_allclose(np.asarray(ops.matmul(x, w, bm=32, bk=64, bn=128)),
                               np.asarray(x @ w), rtol=1e-4, atol=1e-4)