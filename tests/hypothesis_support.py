"""The dedicated guard for the hypothesis-based property modules, plus shared
strategies for the config API.

Import this FIRST in every property-test module:

    from hypothesis_support import given, settings, st

The container CI image does not ship hypothesis (only the GitHub CI install
does, via requirements.txt); `pytest.importorskip` at import time raises
pytest's Skipped, so any module importing this one is skipped whole -- tier-1
stays green wherever hypothesis is absent, without each module repeating the
guard dance.  Not named test_*, so pytest never collects it directly.
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (ACQUISITIONS, BACKENDS, PALLAS_MODES,  # noqa: E402
                        PRUNE_MODES, STRATEGIES, SURROGATES)

# --- CodesignConfig strategies ----------------------------------------------------
# Valid-by-construction section dicts (the from_dict surface): every enumerated
# string from its real choice tuple, every bound respected -- so round-trip
# properties never trip construction-time validation.

search_fields = dict(
    n_trials=st.integers(1, 400),
    n_warmup=st.integers(0, 60),
    pool_size=st.integers(1, 200),
    acquisition=st.sampled_from(ACQUISITIONS),
    lam=st.floats(0.0, 8.0, allow_nan=False, allow_infinity=False),
    surrogate=st.sampled_from(SURROGATES),
    elite_k=st.integers(0, 8),
)

sw_sections = st.fixed_dictionaries({}, optional=search_fields)

hw_sections = st.fixed_dictionaries(
    {},
    optional=dict(search_fields,
                  num_pes=st.sampled_from([64, 128, 168, 256]),
                  spec_k=st.integers(1, 8),
                  prune=st.sampled_from(PRUNE_MODES),
                  prune_margin=st.floats(0.125, 4.0, allow_nan=False,
                                         allow_infinity=False)),
)

engine_sections = st.fixed_dictionaries(
    {},
    optional=dict(
        backend=st.sampled_from([None, *BACKENDS]),
        # probe_fanout/speculative require use_cache=True (validated at
        # construction); the valid-config strategy respects that coupling.
        strategy=st.sampled_from([s for s in STRATEGIES
                                  if s not in ("probe_fanout", "speculative")]),
        gp_refit_every=st.integers(1, 8),
        hw_gp_refit_every=st.integers(1, 8),
        batched=st.booleans(),
        use_cache=st.booleans(),
        gp_rank1_updates=st.booleans(),
        pallas_mode=st.sampled_from([None, *PALLAS_MODES]),
    ),
)

config_dicts = st.fixed_dictionaries(
    {},
    optional=dict(
        sw=sw_sections,
        hw=hw_sections,
        engine=engine_sections,
        seed=st.integers(0, 2**31 - 1),
        verbose=st.booleans(),
    ),
)

# Strings that are NOT one of the given choices (the rejection property).
def not_in(choices):
    return st.text(min_size=1, max_size=12).filter(lambda s: s not in choices)
