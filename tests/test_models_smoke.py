"""Per-architecture smoke tests (reduced configs, CPU) + consistency checks
between the parallel (train/prefill) and recurrent (decode) code paths."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config, get_smoke_config, SHAPES, cell_is_applicable
from repro.models.model import build_model, input_specs
from repro.models import layers as L
from repro.models import xlstm as XL

KEY = jax.random.key(0)
B, S = 2, 32


def _batch(cfg, rng, with_labels=True, S=S):
    batch = {}
    if cfg.family == "encdec":
        batch["src_embeddings"] = jnp.asarray(rng.normal(size=(B, 8, cfg.d_model)), jnp.float32)
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))
    elif cfg.input_mode == "embeddings":
        batch["embeddings"] = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
    else:
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))
    if with_labels:
        batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    rng = np.random.default_rng(0)
    batch = _batch(cfg, rng)
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert jnp.isfinite(loss), arch
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode_shapes(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    rng = np.random.default_rng(1)
    batch = _batch(cfg, rng, with_labels=False)
    logits, cache = jax.jit(model.prefill)(params, batch)
    assert logits.shape[:2] == (B, 1)
    assert bool(jnp.isfinite(logits).all()), arch
    if cfg.family == "encdec" or cfg.input_mode != "embeddings":
        step = {"tokens": jnp.ones((B, 1), jnp.int32)}
    else:
        step = {"embeddings": jnp.asarray(rng.normal(size=(B, 1, cfg.d_model)), jnp.float32)}
    logits2, _ = jax.jit(model.decode_step)(params, cache, step,
                                            jnp.asarray(S - 1, jnp.int32))
    assert bool(jnp.isfinite(logits2).all()), arch


@pytest.mark.parametrize("arch", ["phi3-medium-14b", "qwen3-14b", "moonshot-v1-16b-a3b"])
def test_prefill_decode_consistency(arch):
    """Decode logits at position S from the prefill cache must match a full
    forward over S+1 tokens (cache correctness end-to-end)."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    rng = np.random.default_rng(2)
    toks = rng.integers(0, cfg.vocab_size, (B, S + 1))
    S_max = S + 8
    padded = np.zeros((B, S_max), np.int64)
    padded[:, :S] = toks[:, :S]
    _, cache = jax.jit(model.prefill)(params, {"tokens": jnp.asarray(padded)})
    dec_logits, _ = jax.jit(model.decode_step)(
        params, cache, {"tokens": jnp.asarray(toks[:, S:S + 1])},
        jnp.asarray(S, jnp.int32))
    ref_logits, _ = jax.jit(model.prefill)(params, {"tokens": jnp.asarray(toks)})
    np.testing.assert_allclose(np.asarray(dec_logits[:, 0]),
                               np.asarray(ref_logits[:, -1]),
                               rtol=2e-2, atol=2e-2)


def test_mlstm_chunkwise_matches_recurrent():
    """The chunkwise-parallel mLSTM must equal the naive per-step recurrence."""
    rng = np.random.default_rng(3)
    Bh, Sh, H, dh = 2, 24, 2, 8
    q = jnp.asarray(rng.normal(size=(Bh, Sh, H, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(Bh, Sh, H, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(Bh, Sh, H, dh)), jnp.float32)
    ig = jnp.asarray(rng.normal(size=(Bh, Sh, H)), jnp.float32)
    fg = jnp.asarray(rng.normal(size=(Bh, Sh, H)) + 2.0, jnp.float32)

    for chunk in (1, 4, 8, 24):
        out, _ = XL.mlstm_chunkwise(q, k, v, ig, fg, chunk)
        # reference: strict per-timestep recurrence
        state = (jnp.zeros((Bh, H, dh, dh)), jnp.zeros((Bh, H, dh)),
                 jnp.full((Bh, H), -1e30))
        refs = []
        for t in range(Sh):
            o, state = XL.mlstm_recurrent_step(
                q[:, t], k[:, t], v[:, t], ig[:, t], fg[:, t], state)
            refs.append(o)
        ref = jnp.stack(refs, axis=1)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)


def test_rglru_scan_matches_stepwise():
    from repro.models import rglru as RG
    cfg = get_smoke_config("recurrentgemma-9b")
    p = RG.init_rglru_block(KEY, cfg, jnp.float32)
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(B, 12, cfg.d_model)), jnp.float32)
    full, state_full = RG.rglru_block(p, cfg, x, return_state=True)
    state = RG.init_rglru_state(cfg, B)
    outs = []
    for t in range(12):
        o, state = RG.rglru_block_decode(p, cfg, x[:, t:t + 1], state)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(state_full["h"]), np.asarray(state["h"]),
                               rtol=1e-4, atol=1e-4)


def test_flash_matches_naive_sdpa():
    rng = np.random.default_rng(5)
    for (Bf, Sf, H, KV, hd, win) in [(2, 64, 4, 2, 16, 0), (1, 96, 6, 2, 8, 24)]:
        g = H // KV
        q = jnp.asarray(rng.normal(size=(Bf, Sf, H, hd)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(Bf, Sf, KV, hd)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(Bf, Sf, KV, hd)), jnp.float32)
        ref = L._sdpa(q, k, v, L.causal_mask(Sf, win), g)
        got = L.flash_sdpa(q, k, v, g, win, 32, 32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_int8_kv_cache_roundtrip():
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(size=(2, 1, 4, 16)) * 3, jnp.float32)
    q8, scale = L._quant(x)
    back = L._dequant(q8, scale, jnp.float32)
    assert float(jnp.max(jnp.abs(back - x))) < float(jnp.max(jnp.abs(x))) / 127 * 1.01


def test_long_500k_skip_rules():
    shape = SHAPES["long_500k"]
    runs = {a: cell_is_applicable(get_config(a), shape)[0] for a in ARCH_IDS}
    assert runs["xlstm-1.3b"] and runs["recurrentgemma-9b"]
    assert sum(runs.values()) == 2  # everyone else is full-attention -> skip