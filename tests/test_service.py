"""Co-design service (ISSUE 7): request scheduling, cross-request fusion, the
persistent design store, and session snapshot/resume.

The load-bearing contract is *bit-parity*: a request served by the
`CodesignService` -- its inner searches fused with other requests' into one
stacked dispatch per tick, possibly prefilled from the store -- must produce
exactly the result of running its engine standalone.  That holds because

  * probe seeds are content-derived (`CodesignEngine.probe_seed`), so an
    inner search is the same wherever/whenever it runs;
  * `SearchSession.pending()` is trajectory-neutral (the outer plan is
    cached until `step()` commits it);
  * `bo_maximize_many` stacking is composition-independent within the
    stacked GP's Cholesky regime (budgets here keep every fit inside it --
    see tests/test_layer_batch.py).

Backend comes from REPRO_BACKEND (unset -> numpy), so the same tests pin
parity on both CI jobs.
"""

import dataclasses
import json
import threading

import numpy as np
import pytest

from repro.core import (CodesignConfig, CodesignEngine, EngineConfig,
                        HWSearchConfig, LRUCache, ServiceConfig,
                        SWSearchConfig, SearchSession, codesign)
from repro.core import nested as nested_mod
from repro.service import (CodesignService, DesignStore, ServiceRequest,
                           design_key)
from repro.timeloop import MODEL_LAYERS


def svc_config(seed=0, strategy="sequential", n_hw=4, **eng):
    # sw n_trials=12 keeps every stacked GP fit in the Cholesky regime where
    # cross-request stacking is bit-identical to standalone searches.
    return CodesignConfig(
        sw=SWSearchConfig(n_trials=12, n_warmup=5, pool_size=15),
        hw=HWSearchConfig(n_trials=n_hw, n_warmup=2, pool_size=15, spec_k=2),
        engine=EngineConfig(strategy=strategy, **eng),
        seed=seed)


MIXED_REQUESTS = [  # mixed workloads x strategies x seeds
    ("dqn", svc_config(0, "sequential")),
    ("mlp", svc_config(1, "speculative")),
    ("dqn", svc_config(2, "layer_batched")),
    ("mlp", svc_config(3, "probe_fanout")),
]


def _standalone(model, config):
    return CodesignEngine(config).run(MODEL_LAYERS[model])


def _assert_parity(got, ref, where=""):
    assert got.best_hw == ref.best_hw, where
    assert got.best_model_edp == ref.best_model_edp, where
    assert got.best_mappings == ref.best_mappings, where
    assert np.array_equal(got.hw_result.history, ref.hw_result.history), where
    assert got.hw_result.points == ref.hw_result.points, where


class _FanoutSpy:
    """Record every stacked dispatch `optimize_software_fanout` runs."""

    def __init__(self):
        self.calls = []

    def __enter__(self):
        self._orig = nested_mod.optimize_software_fanout

        def spy(items, *a, **kw):
            self.calls.append(list(items))
            return self._orig(items, *a, **kw)

        # Every executor path -- the scheduler's FanoutSearchSpec.run and
        # the engine's fanout() alike -- resolves the function through the
        # module attribute at call time, so patching here sees them all.
        nested_mod.optimize_software_fanout = spy
        return self

    def __exit__(self, *exc):
        nested_mod.optimize_software_fanout = self._orig


# --- cross-request parity ---------------------------------------------------------


@pytest.mark.parametrize("fuse", [True, False])
def test_concurrent_requests_match_standalone(fuse):
    """N mixed concurrent requests through the service == N standalone runs,
    with and without cross-request fusion (fusion only moves work)."""
    refs = [_standalone(m, c) for m, c in MIXED_REQUESTS]
    svc = CodesignService(ServiceConfig(max_slots=len(MIXED_REQUESTS),
                                        fuse=fuse))
    rids = [svc.submit(ServiceRequest(layers=tuple(MODEL_LAYERS[m]), config=c))
            for m, c in MIXED_REQUESTS]
    responses = svc.run()
    assert set(responses) == set(rids)
    for rid, ref in zip(rids, refs):
        _assert_parity(responses[rid].result, ref, where=rid)
        stats = responses[rid].result.stats
        assert stats["latency_s"] > 0 and stats["ticks"] > 0


def test_staggered_admission_matches_standalone():
    """max_slots < N: requests are admitted as slots free up (different
    n_trials retire at different ticks) -- parity must survive sessions
    joining mid-stream."""
    reqs = [("dqn", svc_config(0, n_hw=3)), ("mlp", svc_config(1, n_hw=5)),
            ("dqn", svc_config(2, n_hw=4)), ("mlp", svc_config(3, n_hw=3))]
    refs = [_standalone(m, c) for m, c in reqs]
    svc = CodesignService(ServiceConfig(max_slots=2))
    rids = [svc.submit(ServiceRequest(layers=tuple(MODEL_LAYERS[m]), config=c))
            for m, c in reqs]
    responses = svc.run()
    for rid, ref in zip(rids, refs):
        _assert_parity(responses[rid].result, ref, where=rid)


def test_identical_requests_dedup_to_one_search_stream():
    """Two identical concurrent requests need each (hw, layer) search ONCE:
    equal design keys collapse across requests, both sessions get the same
    prefilled entries, both results match standalone."""
    ref = _standalone("dqn", svc_config(7))
    svc = CodesignService(ServiceConfig(max_slots=2))
    with _FanoutSpy() as spy:
        rids = [svc.submit(ServiceRequest(layers=tuple(MODEL_LAYERS["dqn"]),
                                          config=svc_config(7)))
                for _ in range(2)]
        responses = svc.run()
    for rid in rids:
        _assert_parity(responses[rid].result, ref, where=rid)
    searched = [it for call in spy.calls for it in call]
    assert len(searched) == len(set(searched))  # nothing dispatched twice
    assert svc.stats["deduped_items"] > 0


def test_fused_dispatch_count():
    """With fusion on, every tick issues at most ONE stacked dispatch for
    requests sharing a search config (the cross-request fusion claim, counted
    at the dispatch site)."""
    svc = CodesignService(ServiceConfig(max_slots=3, fuse=True))
    with _FanoutSpy() as spy:
        for seed, model in enumerate(("dqn", "mlp", "dqn")):
            svc.submit(ServiceRequest(layers=tuple(MODEL_LAYERS[model]),
                                      config=svc_config(seed)))
        svc.run()
    assert len(spy.calls) == svc.stats["fused_dispatches"]
    assert len(spy.calls) <= svc.stats["ticks"]
    # and the fused streams really carried several requests' work: some
    # dispatch mixes more than one hardware point's items
    assert any(len({hw for hw, _ in call}) > 1 for call in spy.calls)


# --- the design store -------------------------------------------------------------


def test_store_roundtrip_feasible_and_infeasible(tmp_path):
    from repro.timeloop import eyeriss_168
    from repro.core.nested import optimize_software

    hw = eyeriss_168()
    layer = MODEL_LAYERS["dqn"][0]
    cfg = svc_config(0)
    r = optimize_software(hw, layer, cfg.sw, seed=3, engine=cfg.engine)
    entry = nested_mod._cache_entry(hw, layer, r)

    store = DesignStore(str(tmp_path))
    key = design_key(hw, layer, cfg.sw, cfg.engine, 3)
    assert store.get(key) is None and store.misses == 1
    store.put(key, entry)
    assert store.get(key) == entry  # exact mapping + exact float EDP
    assert store.hits == 1 and len(store) == 1

    store.put("beef" * 8, (None, float("inf")))  # infeasibility is cached too
    assert store.get("beef" * 8) == (None, float("inf"))
    assert len(store) == 2


def test_design_key_separates_what_changes_the_search():
    from repro.timeloop import eyeriss_168

    hw = eyeriss_168()
    layer = MODEL_LAYERS["dqn"][0]
    cfg = svc_config(0)
    base = design_key(hw, layer, cfg.sw, cfg.engine, 3)
    assert base == design_key(hw, layer, cfg.sw, cfg.engine, 3)
    # strategy moves work around, never changes a search -> same key
    assert base == design_key(
        hw, layer, cfg.sw,
        dataclasses.replace(cfg.engine, strategy="speculative"), 3)
    for other in (
        design_key(hw, layer, cfg.sw, cfg.engine, 4),
        design_key(hw, MODEL_LAYERS["dqn"][1], cfg.sw, cfg.engine, 3),
        design_key(hw, layer, dataclasses.replace(cfg.sw, n_trials=13),
                   cfg.engine, 3),
        design_key(hw, layer, cfg.sw,
                   dataclasses.replace(cfg.engine, gp_refit_every=2), 3),
    ):
        assert other != base


def test_warm_store_rerun_runs_zero_inner_searches(tmp_path):
    """The store acceptance criterion: resubmitting a served workload against
    the same store performs ZERO inner mapping searches -- every (hw, layer)
    result is an exact replay from disk -- and still returns the standalone
    result bit-for-bit."""
    reqs = MIXED_REQUESTS[:2]
    refs = [_standalone(m, c) for m, c in reqs]
    sc = ServiceConfig(max_slots=2, store_dir=str(tmp_path))

    cold = CodesignService(sc)
    rids = [cold.submit(ServiceRequest(layers=tuple(MODEL_LAYERS[m]),
                                       config=c)) for m, c in reqs]
    cold_resp = cold.run()
    assert all(cold_resp[r].result.stats["store_misses"] > 0 for r in rids)
    assert len(cold.store) > 0

    warm = CodesignService(sc)
    with _FanoutSpy() as spy:
        rids2 = [warm.submit(ServiceRequest(layers=tuple(MODEL_LAYERS[m]),
                                            config=c)) for m, c in reqs]
        warm_resp = warm.run()
    assert spy.calls == []  # zero inner searches
    for rid, ref in zip(rids2, refs):
        _assert_parity(warm_resp[rid].result, ref, where=rid)
        stats = warm_resp[rid].result.stats
        assert stats["store_misses"] == 0 and stats["store_hits"] > 0


# --- executor fan-out + overlapped ticks (ISSUE 8) --------------------------------


@pytest.fixture(scope="module")
def service_pool():
    """One shared 2-worker pool for the service-executor tests (spawn +
    import cost paid once)."""
    from repro.parallel.executor import ProcessExecutor

    ex = ProcessExecutor(n_workers=2)
    yield ex
    ex.close()


def test_process_executor_service_matches_standalone(service_pool):
    """The mixed batch through a process-executor service -- overlapped
    ticks: sessions park while their fused dispatches are in flight, step
    as results land -- is bit-identical to standalone runs."""
    refs = [_standalone(m, c) for m, c in MIXED_REQUESTS]
    svc = CodesignService(ServiceConfig(max_slots=len(MIXED_REQUESTS)),
                          executor=service_pool)
    rids = [svc.submit(ServiceRequest(layers=tuple(MODEL_LAYERS[m]), config=c))
            for m, c in MIXED_REQUESTS]
    responses = svc.run()
    for rid, ref in zip(rids, refs):
        _assert_parity(responses[rid].result, ref, where=rid)
    assert not svc._inflight and not svc._owners  # nothing leaked in flight


def test_mixed_fuse_groups_stagger_under_executor(service_pool):
    """Staggered admission with INCOMPATIBLE configs (different sw budgets):
    requests with different sw_cfg must land in separate fuse groups --
    every submitted spec carries exactly one config, and both configs'
    groups are dispatched -- and still match standalone parity."""
    cfg_a = svc_config(0, n_hw=3)
    cfg_b = dataclasses.replace(
        svc_config(1, n_hw=4),
        sw=SWSearchConfig(n_trials=10, n_warmup=4, pool_size=14))
    reqs = [("dqn", cfg_a), ("mlp", cfg_b), ("dqn", cfg_b),
            ("mlp", dataclasses.replace(cfg_a, seed=9))]
    refs = [_standalone(m, c) for m, c in reqs]

    svc = CodesignService(ServiceConfig(max_slots=2), executor=service_pool)
    submitted = []
    orig_submit = svc.executor.submit

    def spy_submit(jid, spec):
        submitted.append(spec)
        return orig_submit(jid, spec)

    svc.executor.submit = spy_submit
    try:
        rids = [svc.submit(ServiceRequest(layers=tuple(MODEL_LAYERS[m]),
                                          config=c)) for m, c in reqs]
        responses = svc.run()
    finally:
        svc.executor.submit = orig_submit
    for rid, ref in zip(rids, refs):
        _assert_parity(responses[rid].result, ref, where=rid)
    assert len(submitted) == svc.stats["fused_dispatches"]
    assert {s.sw for s in submitted} == {cfg_a.sw, cfg_b.sw}


def test_priority_orders_admission():
    """max_slots=1 serializes the slot: the high-priority request admits --
    and with equal budgets completes -- first even when submitted last;
    FIFO order is preserved within a priority level."""
    svc = CodesignService(ServiceConfig(max_slots=1))
    layers = tuple(MODEL_LAYERS["dqn"])
    lo1 = svc.submit(ServiceRequest(layers=layers, config=svc_config(0, n_hw=3)))
    lo2 = svc.submit(ServiceRequest(layers=layers, config=svc_config(1, n_hw=3)))
    hi = svc.submit(ServiceRequest(layers=layers, config=svc_config(2, n_hw=3),
                                   priority=3))
    responses = svc.run()
    assert list(responses) == [hi, lo1, lo2]


def test_request_priority_validation_and_roundtrip():
    req = ServiceRequest(layers=tuple(MODEL_LAYERS["dqn"]), priority=5,
                         config=svc_config(2), rid="p")
    assert ServiceRequest.from_json(req.to_json()) == req
    assert ServiceRequest.from_dict({"layers": "dqn"}).priority == 0
    with pytest.raises(ValueError, match="priority"):
        ServiceRequest(layers=tuple(MODEL_LAYERS["dqn"]), priority="high")
    with pytest.raises(ValueError, match="priority"):
        ServiceRequest(layers=tuple(MODEL_LAYERS["dqn"]), priority=True)


# --- store stats + prune (ISSUE 8) ------------------------------------------------


def test_store_stats_and_oldest_first_prune(tmp_path):
    import os

    store = DesignStore(str(tmp_path))
    keys = [f"{i:02x}" + "f" * 30 for i in range(6)]  # one shard each
    for i, key in enumerate(keys):
        store.put(key, (None, float("inf")))
        os.utime(store._path(key), (1000.0 + i, 1000.0 + i))
    st = store.stats()
    assert st["entries"] == 6 == len(store)
    assert st["bytes"] > 0
    assert len(st["shards"]) == 6
    assert all(s == {"entries": 1, "bytes": st["bytes"] // 6}
               for s in st["shards"].values())

    assert store.prune(2) == 4  # oldest four evicted
    assert store.stats()["entries"] == 2
    assert store.get(keys[-1]) is not None  # newest survive
    assert store.get(keys[-2]) is not None
    assert store.get(keys[0]) is None
    assert store.prune(2) == 0  # idempotent at the bound
    assert store.prune(0) == 2  # full eviction
    assert len(store) == 0
    with pytest.raises(ValueError):
        store.prune(-1)
    with pytest.raises(ValueError):
        store.prune(2.5)


# --- session snapshot / resume ----------------------------------------------------


def test_session_snapshot_restore_resumes_bit_identically():
    """Interrupt a session halfway, snapshot, restore into a FRESH engine +
    session, finish there: the result equals the uninterrupted run (GP refit
    from the data prefix is deterministic; the cache rides in the
    snapshot)."""
    cfg = svc_config(5, "speculative", n_hw=6)
    layers = MODEL_LAYERS["dqn"]
    ref = CodesignEngine(cfg).run(layers)

    first = CodesignEngine(cfg).session(layers)
    for _ in range(3):
        assert first.step()
    snap = first.snapshot()

    resumed = CodesignEngine(cfg).session(layers).restore(snap)
    while resumed.step():
        pass
    _assert_parity(resumed.result(), ref)


def test_snapshot_refuses_mid_trial():
    cfg = svc_config(0)
    session = CodesignEngine(cfg).session(MODEL_LAYERS["dqn"])
    session.pending()  # plans the warmup block without committing it
    with pytest.raises(RuntimeError):
        session.snapshot()
    assert session.step()  # the cached plan commits; the session continues


def test_pending_is_trajectory_neutral():
    """Calling pending() (any number of times) before each step cannot change
    the trajectory: the outer plan is cached until committed."""
    cfg = svc_config(4)
    layers = MODEL_LAYERS["mlp"]
    ref = CodesignEngine(cfg).run(layers)
    session = CodesignEngine(cfg).session(layers)
    while True:
        items, seeds = session.pending()
        assert len(items) == len(seeds)
        assert session.pending()[0] == items  # cached plan -> same answer
        if not session.step():
            break
    _assert_parity(session.result(), ref)


# --- legacy shim ------------------------------------------------------------------


def test_legacy_shim_routes_through_search_session():
    """codesign(**legacy_kwargs) emits ONE consolidated DeprecationWarning and
    drives the same SearchSession machinery as the config API."""
    sessions = []
    orig = nested_mod.SearchSession

    class SpySession(orig):
        def __init__(self, *a, **kw):
            sessions.append(self)
            super().__init__(*a, **kw)

    nested_mod.SearchSession = SpySession
    try:
        with pytest.warns(DeprecationWarning) as record:
            codesign(MODEL_LAYERS["dqn"], n_hw_trials=3, n_hw_warmup=2,
                     n_sw_trials=10, n_sw_warmup=4, sw_pool=15, hw_pool=15)
    finally:
        nested_mod.SearchSession = orig
    assert len(record) == 1  # one consolidated warning
    assert len(sessions) == 1  # the run was the session, stepped through


# --- config + request surface -----------------------------------------------------


def test_service_config_validation_and_roundtrip():
    sc = ServiceConfig(max_slots=2, fuse=False, store_dir="/tmp/x",
                       cache_entries=10)
    assert ServiceConfig.from_dict(sc.to_dict()) == sc
    with pytest.raises(ValueError):
        ServiceConfig(max_slots=0)
    with pytest.raises(ValueError):
        ServiceConfig(cache_entries=-1)
    with pytest.raises(ValueError):
        ServiceConfig(store_dir=7)
    with pytest.raises(ValueError):
        ServiceConfig.from_dict({"bogus": 1})


def test_request_json_roundtrip_and_model_names():
    req = ServiceRequest(layers=tuple(MODEL_LAYERS["dqn"]),
                         config=svc_config(2), rid="abc")
    back = ServiceRequest.from_json(req.to_json())
    assert back == req
    named = ServiceRequest.from_dict({"layers": "mlp"})
    assert named.layers == tuple(MODEL_LAYERS["mlp"])
    assert named.config == CodesignConfig()
    with pytest.raises(ValueError):
        ServiceRequest.from_dict({"layers": "nope"})
    with pytest.raises(ValueError):
        ServiceRequest.from_dict({"layers": "dqn", "bogus": 1})
    with pytest.raises(ValueError):
        ServiceRequest(layers=())


def test_submit_accepts_json_and_rejects_duplicate_rids():
    svc = CodesignService(ServiceConfig(max_slots=1))
    rid = svc.submit(json.dumps({"layers": "dqn", "rid": "x",
                                 "config": svc_config(0).to_dict()}))
    assert rid == "x"
    with pytest.raises(ValueError):
        svc.submit(ServiceRequest(layers=tuple(MODEL_LAYERS["dqn"]),
                                  rid="x"))
    assert svc.submit(ServiceRequest(layers=tuple(MODEL_LAYERS["dqn"]))) \
        .startswith("r")


# --- bounded caches ---------------------------------------------------------------


def test_lru_cache_bounds_and_counts():
    c = LRUCache(maxsize=2)
    c["a"], c["b"] = 1, 2
    assert c["a"] == 1  # refreshes recency
    c["c"] = 3          # evicts "b" (least recent)
    assert "b" not in c and "a" in c and "c" in c
    assert c.evictions == 1
    assert c.hits == 3          # the read + two membership hits
    assert c.misses == 1        # the "b" probe
    unbounded = LRUCache(0)
    for i in range(100):
        unbounded[i] = i
    assert len(unbounded) == 100 and unbounded.evictions == 0


def test_service_applies_cache_bound_to_requests():
    """A request that leaves engine.cache_entries at 0 gets the service-level
    LRU bound; eviction accounting surfaces in its result stats."""
    svc = CodesignService(ServiceConfig(max_slots=1, cache_entries=3))
    rid = svc.submit(ServiceRequest(layers=tuple(MODEL_LAYERS["dqn"]),
                                    config=svc_config(0)))
    stats = svc.run()[rid].result.stats
    assert stats["cache_size"] <= 3
    assert stats["cache_evictions"] > 0


# --- checkpoint writer fixes ------------------------------------------------------


def _tree(step):
    return {"w": np.full((4, 3), float(step)), "b": np.arange(3.0) + step}


def test_concurrent_checkpoint_saves_are_safe(tmp_path):
    """Many threads saving different steps into ONE directory: no torn step
    dirs, LATEST points at the highest step, restore succeeds."""
    from repro.checkpoint import checkpoint as ckpt

    steps = list(range(8))
    threads = [threading.Thread(target=ckpt.save,
                                args=(str(tmp_path), s, _tree(s)))
               for s in steps]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert ckpt.latest_step(str(tmp_path)) == max(steps)
    state, step = ckpt.restore(str(tmp_path), _tree(0))
    assert step == max(steps)
    np.testing.assert_array_equal(state["w"], _tree(step)["w"])
    leftovers = [n for n in tmp_path.iterdir() if ".tmp" in n.name]
    assert leftovers == []


def test_latest_pointer_is_monotone(tmp_path):
    """A slow writer finishing an OLD step must not move LATEST backwards."""
    from repro.checkpoint import checkpoint as ckpt

    ckpt.save(str(tmp_path), 5, _tree(5))
    ckpt.save(str(tmp_path), 3, _tree(3))  # late low-step save
    assert ckpt.latest_step(str(tmp_path)) == 5
    state, step = ckpt.restore(str(tmp_path), _tree(0), step=3)
    assert step == 3  # the old step is still restorable by name


def test_async_checkpointer_close_joins_and_reraises(tmp_path):
    from repro.checkpoint import checkpoint as ckpt

    with ckpt.AsyncCheckpointer(str(tmp_path)) as cp:
        cp.save(1, _tree(1))
        cp.save(2, _tree(2))  # waits for save 1 first
    assert cp.last_saved == 2
    assert cp._thread is None  # close() joined the writer
    assert ckpt.latest_step(str(tmp_path)) == 2

    bad = ckpt.AsyncCheckpointer(str(tmp_path / "missing" / "\0bad"))
    bad.save(1, _tree(1))
    with pytest.raises(ValueError):
        bad.close()
    bad.close()  # error is raised once, then the checkpointer is clean
