"""The typed config API (ISSUE 4): `CodesignConfig` construction/validation/
JSON round-trip, the `codesign(**legacy_kwargs)` deprecation shim's pinned
result parity against `CodesignEngine(config).run()` on both backends, and the
`probe_fanout` strategy's exact reproduction of the sequential outer-loop
warmup (same seeds -> same probes, same EDPs, same histories).

Budgets stay inside the stacked GP's Cholesky regime (see
tests/test_layer_batch.py), where all strategies are bit-identical.
"""

import dataclasses
import json

import numpy as np
import pytest

from repro.core import (CodesignConfig, CodesignEngine, EngineConfig,
                        HWSearchConfig, SWSearchConfig, bo_maximize,
                        bo_maximize_many, codesign, config_from_legacy_kwargs,
                        optimize_software, optimize_software_fanout)
from repro.core.nested import PROBE_STRATEGIES
from repro.core.swspace import SoftwareSpace
from repro.timeloop import MODEL_LAYERS, eyeriss_168
from repro.timeloop import batch as tlb
from repro.timeloop import batch_jax as jtlb
from repro.timeloop.arch import sample_hardware_pool


def small_config(strategy="auto", backend=None, **top):
    # 3 warmup probes (the fan-out) + 1 scored trial (the per-probe path).
    return CodesignConfig(
        sw=SWSearchConfig(n_trials=12, n_warmup=6, pool_size=20),
        hw=HWSearchConfig(n_trials=4, n_warmup=3, pool_size=20),
        engine=EngineConfig(backend=backend, strategy=strategy),
        **top)


# --- construction + serialization -----------------------------------------------


def test_json_round_trip():
    cfg = CodesignConfig(
        sw=SWSearchConfig(n_trials=42, acquisition="ei", lam=0.5),
        hw=HWSearchConfig(n_trials=7, num_pes=256, surrogate="gp_se"),
        engine=EngineConfig(backend="jax", strategy="probe_fanout",
                            gp_refit_every=3, pallas_mode="interpret"),
        seed=11, verbose=True)
    d = json.loads(json.dumps(cfg.to_dict()))  # through real JSON
    assert CodesignConfig.from_dict(d) == cfg
    assert CodesignConfig.from_json(cfg.to_json()) == cfg


def test_from_dict_partial_and_defaults():
    cfg = CodesignConfig.from_dict({"sw": {"n_trials": 9}, "seed": 4})
    assert cfg.sw.n_trials == 9 and cfg.sw.n_warmup == 30
    assert cfg.hw == HWSearchConfig() and cfg.seed == 4
    assert CodesignConfig.from_dict({}) == CodesignConfig()


@pytest.mark.parametrize("bad", [
    lambda: SWSearchConfig(acquisition="ucb"),
    lambda: SWSearchConfig(surrogate="mlp"),
    lambda: SWSearchConfig(n_trials=0),
    lambda: HWSearchConfig(num_pes=-1),
    lambda: EngineConfig(backend="torch"),
    lambda: EngineConfig(strategy="fanout"),
    lambda: EngineConfig(pallas_mode="triton"),
    lambda: EngineConfig(gp_refit_every=0),
    lambda: EngineConfig(strategy="probe_fanout", use_cache=False),
    lambda: CodesignConfig.from_dict({"sw": {"n_trial": 5}}),  # typo'd field
    lambda: CodesignConfig(sw=HWSearchConfig()),  # wrong section type
])
def test_bad_values_raise_at_construction(bad):
    """Every enumerated string / bound is validated at config construction
    (the one ValueError site), not at some deep call site."""
    with pytest.raises(ValueError):
        bad()


def test_space_validation_shares_the_choice_site():
    with pytest.raises(ValueError):
        SoftwareSpace(eyeriss_168(), MODEL_LAYERS["dqn"][0], backend="torch")
    with pytest.raises(ValueError):
        SoftwareSpace(eyeriss_168(), MODEL_LAYERS["dqn"][0],
                      pallas_mode="triton")


def test_legacy_kwarg_mapping():
    cfg = config_from_legacy_kwargs(
        n_hw_trials=5, n_sw_trials=30, n_sw_warmup=10, sw_pool=40, hw_pool=50,
        num_pes=256, acquisition="ei", lam=2.0, surrogate="gp_se",
        backend="jax", layer_batched=True, gp_refit_every=2, seed=3,
        verbose=True)
    assert cfg.hw.n_trials == 5 and cfg.hw.pool_size == 50
    assert cfg.sw.n_trials == 30 and cfg.sw.n_warmup == 10
    assert cfg.sw.acquisition == cfg.hw.acquisition == "ei"
    assert cfg.sw.lam == cfg.hw.lam == 2.0
    assert cfg.hw.num_pes == 256
    assert cfg.engine.strategy == "layer_batched"
    assert cfg.engine.gp_refit_every == 2
    assert cfg.seed == 3 and cfg.verbose
    assert config_from_legacy_kwargs(layer_batched=None).engine.strategy == "auto"
    assert config_from_legacy_kwargs(layer_batched=False).engine.strategy == "sequential"
    with pytest.raises(TypeError):
        config_from_legacy_kwargs(n_trials=5)  # not a legacy codesign kwarg


# --- legacy shim parity ---------------------------------------------------------


LEGACY = dict(n_hw_trials=4, n_hw_warmup=3, hw_pool=20, n_sw_trials=12,
              n_sw_warmup=6, sw_pool=20, seed=0)


def _assert_codesign_parity(a, b):
    assert a.best_hw == b.best_hw
    assert a.best_model_edp == b.best_model_edp
    assert a.best_mappings == b.best_mappings
    assert np.array_equal(a.hw_result.history, b.hw_result.history)
    assert a.hw_result.n_infeasible == b.hw_result.n_infeasible


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_legacy_shim_matches_engine(backend):
    """Seeded `codesign(**legacy_kwargs)` (DeprecationWarning) and
    `CodesignEngine(config).run()` produce identical best-EDP/history."""
    layers = MODEL_LAYERS["dqn"]
    with pytest.deprecated_call():
        old = codesign(layers, backend=backend, **LEGACY)
    new = CodesignEngine(small_config(backend=backend)).run(layers)
    _assert_codesign_parity(old, new)
    # the blessed non-deprecated spellings
    via_config = codesign(layers, config=small_config(backend=backend))
    _assert_codesign_parity(via_config, new)
    with pytest.raises(TypeError):
        codesign(layers, config=small_config(), n_hw_trials=3)  # not both


# --- probe fan-out --------------------------------------------------------------


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_probe_fanout_matches_sequential_warmup(backend):
    """The H*L*B stacked warmup fan-out reproduces the sequential outer-loop
    warmup exactly: same probes, same per-probe EDPs, same histories."""
    layers = MODEL_LAYERS["dqn"]
    results = {}
    for strategy in ("sequential", "layer_batched", "probe_fanout"):
        eng = CodesignEngine(small_config(strategy=strategy, backend=backend))
        results[strategy] = eng.run(layers)
        assert eng.strategy_name == strategy
    _assert_codesign_parity(results["probe_fanout"], results["sequential"])
    _assert_codesign_parity(results["probe_fanout"], results["layer_batched"])


def test_probe_fanout_prefills_cache_for_all_warmup_probes():
    """After the warmup round every (probe, layer) pair the fan-out searched
    is a cache hit -- eval_hw never re-runs an inner search for them."""
    layers = MODEL_LAYERS["mlp"]
    eng = CodesignEngine(small_config(strategy="probe_fanout"))
    seen = []
    orig = PROBE_STRATEGIES["probe_fanout"].evaluate_probe

    def spying(self, engine, hw, seed):
        before = set(engine.cache)
        orig(self, engine, hw, seed)
        seen.append(set(engine.cache) - before)

    PROBE_STRATEGIES["probe_fanout"].evaluate_probe = spying
    try:
        eng.run(layers)
    finally:
        PROBE_STRATEGIES["probe_fanout"].evaluate_probe = orig
    n_warm = eng.config.hw.n_warmup
    assert len(seen) == eng.config.hw.n_trials
    assert all(not new for new in seen[:n_warm])  # warmup: all cache hits


def test_optimize_software_fanout_matches_per_probe():
    """`optimize_software_fanout` over (hw, layer) items spanning different
    hardware probes reproduces the per-probe `optimize_software` runs."""
    rng = np.random.default_rng(0)
    hws = sample_hardware_pool(rng, 2, num_pes=168)
    layers = MODEL_LAYERS["dqn"]
    cfg = SWSearchConfig(n_trials=12, n_warmup=6, pool_size=20)
    items = [(hw, layer) for hw in hws for layer in layers]
    seeds = [11 + i for i, hw in enumerate(hws) for _ in layers]
    fan = optimize_software_fanout(items, cfg, seeds=seeds)
    for (hw, layer), s, r in zip(items, seeds, fan):
        ref = optimize_software(hw, layer, cfg, seed=s)
        assert r.best_point == ref.best_point
        assert np.array_equal(r.history, ref.history)


def test_forward_device_stacked_per_probe_hw():
    """The stacked fused program with per-run hardware vectors computes per
    row exactly what per-(hw, layer) forward_device calls compute."""
    rng = np.random.default_rng(1)
    hws = sample_hardware_pool(rng, 3, num_pes=168)
    layers = [MODEL_LAYERS["dqn"][0], MODEL_LAYERS["resnet"][1],
              MODEL_LAYERS["mlp"][0]]
    pools = [tlb.sample_valid_pool(rng, hw, ly, 10)
             for hw, ly in zip(hws, layers)]
    out = jtlb.forward_device_stacked(hws, pools, layers)
    for k, (hw, p, ly) in enumerate(zip(hws, pools, layers)):
        ref = jtlb.forward_device(hw, p, ly)
        np.testing.assert_array_equal(
            np.asarray(out["valid"][k]), np.asarray(ref["valid"]))
        for key in ("edp", "utility", "features"):
            np.testing.assert_allclose(
                np.asarray(out[key][k]), np.asarray(ref[key]), rtol=1e-12)


def test_bo_maximize_many_per_run_seeds():
    """A seed sequence gives each lockstep run its own stream, matching the
    individually-seeded sequential calls; a wrong-length sequence is loud."""
    hw = eyeriss_168()
    layers = MODEL_LAYERS["dqn"]
    spaces = [SoftwareSpace(hw, ly) for ly in layers]
    cfg = SWSearchConfig(n_trials=12, n_warmup=6, pool_size=20)
    many = bo_maximize_many(spaces, cfg, seed=[5, 9])
    for ly, s, r in zip(layers, (5, 9), many):
        ref = bo_maximize(SoftwareSpace(hw, ly), cfg, seed=s)
        assert r.best_point == ref.best_point
        assert np.array_equal(r.history, ref.history)
    with pytest.raises(ValueError):
        bo_maximize_many(spaces, cfg, seed=[1, 2, 3])


# --- config-vs-kwarg equivalence of the mid-level entry points ------------------


def test_optimize_software_config_equals_kwargs():
    hw = eyeriss_168()
    layer = MODEL_LAYERS["dqn"][1]
    cfg = SWSearchConfig(n_trials=14, n_warmup=6, pool_size=20,
                         acquisition="ei")
    a = optimize_software(hw, layer, cfg, seed=2)
    b = optimize_software(hw, layer, n_trials=14, n_warmup=6, pool_size=20,
                          acquisition="ei", seed=2)
    assert a.best_point == b.best_point and np.array_equal(a.history, b.history)
    with pytest.raises(TypeError):
        optimize_software(hw, layer, pool=20)  # unknown override is loud


def test_positional_legacy_callers_break_loudly():
    """Pre-config POSITIONAL callers (codesign(layers, 256),
    optimize_software(hw, layer, 100), bo_maximize(space, 100)) bind to the
    new config parameter; they get a descriptive TypeError at the entry
    point, not a deep AttributeError."""
    hw = eyeriss_168()
    layer = MODEL_LAYERS["dqn"][0]
    with pytest.raises(TypeError, match="CodesignConfig"):
        codesign(MODEL_LAYERS["dqn"], 256)
    with pytest.raises(TypeError, match="SearchConfig"):
        optimize_software(hw, layer, 100)
    with pytest.raises(TypeError, match="SearchConfig"):
        bo_maximize(SoftwareSpace(hw, layer), 100)


def test_bo_maximize_config_equals_kwargs():
    hw = eyeriss_168()
    space = SoftwareSpace(hw, MODEL_LAYERS["mlp"][0])
    cfg = SWSearchConfig(n_trials=14, n_warmup=6, pool_size=20)
    a = bo_maximize(space, cfg, seed=1)
    b = bo_maximize(SoftwareSpace(hw, MODEL_LAYERS["mlp"][0]),
                    n_trials=14, n_warmup=6, pool_size=20, seed=1)
    assert a.best_point == b.best_point and np.array_equal(a.history, b.history)
