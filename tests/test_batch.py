"""Parity of the batched evaluation engine (`repro.timeloop.batch`) against the
scalar reference, plus validity guarantees of the vectorized pool sampler."""

import numpy as np
import pytest

from repro.core.bo import bo_maximize
from repro.core.swspace import SoftwareSpace
from repro.timeloop import PAPER_WORKLOADS, evaluate, eyeriss_168
from repro.timeloop import batch as tlb
from repro.timeloop.mapping import (constrained_random_mapping,
                                    mapping_is_valid, random_mapping)

LAYERS = ["ResNet-K1", "ResNet-K4", "DQN-K1", "DQN-K2", "MLP-K2", "Transformer-K2"]
RTOL = 1e-9


def _random_pool(layer, n=200, seed=0):
    """Half naive draws (exercises invalid rows), half constraint-aware."""
    hw = eyeriss_168()
    rng = np.random.default_rng(seed)
    ms = [random_mapping(rng, hw, layer) for _ in range(n // 2)]
    ms += [constrained_random_mapping(rng, hw, layer) for _ in range(n - n // 2)]
    return hw, ms


def test_pack_unpack_roundtrip():
    hw, ms = _random_pool(PAPER_WORKLOADS["DQN-K2"], n=50)
    mb = tlb.pack(ms)
    assert len(mb) == 50
    for i in (0, 7, 49):
        assert mb[i] == ms[i]


@pytest.mark.parametrize("name", LAYERS)
def test_batched_validity_matches_scalar(name):
    layer = PAPER_WORKLOADS[name]
    hw, ms = _random_pool(layer)
    ok = tlb.valid_batch(tlb.pack(ms), hw, layer)
    for i, m in enumerate(ms):
        assert bool(ok[i]) == mapping_is_valid(m, hw, layer)[0]


@pytest.mark.parametrize("name", LAYERS)
def test_batched_edp_matches_scalar(name):
    layer = PAPER_WORKLOADS[name]
    hw, ms = _random_pool(layer)
    ev = tlb.evaluate_batch(hw, tlb.pack(ms), layer)
    n_valid = 0
    for i, m in enumerate(ms):
        ref = evaluate(hw, m, layer)
        assert bool(ev["valid"][i]) == ref.valid
        if not ref.valid:
            assert np.isinf(ev["edp"][i])
            continue
        n_valid += 1
        for key in ("energy_pj", "delay_cycles", "edp"):
            a, b = getattr(ref, key), ev[key][i]
            assert abs(a - b) <= RTOL * max(abs(a), abs(b)), (name, i, key)
    assert n_valid > 10  # the comparison actually exercised valid rows


@pytest.mark.parametrize("name", LAYERS)
def test_batched_features_match_scalar(name):
    layer = PAPER_WORKLOADS[name]
    hw, ms = _random_pool(layer)
    space = SoftwareSpace(hw, layer)
    feats = tlb.features_batch(tlb.pack(ms), hw, layer)
    assert feats.shape == (len(ms), space.feature_dim)
    for i, m in enumerate(ms):
        np.testing.assert_allclose(feats[i], space.features(m), rtol=RTOL)


@pytest.mark.parametrize("name", ["ResNet-K2", "DQN-K1", "Transformer-K1"])
def test_vectorized_pool_sampler_emits_only_valid(name):
    layer = PAPER_WORKLOADS[name]
    hw = eyeriss_168()
    rng = np.random.default_rng(1)
    pool = tlb.sample_valid_pool(rng, hw, layer, 150)
    assert pool is not None and len(pool) == 150
    assert tlb.valid_batch(pool, hw, layer).all()
    # spot-check against the scalar validity oracle
    for i in range(0, 150, 13):
        ok, why = mapping_is_valid(pool[i], hw, layer)
        assert ok, why


def test_pool_sampler_respects_dataflow_pins():
    import dataclasses

    layer = PAPER_WORKLOADS["DQN-K1"]
    hw = dataclasses.replace(eyeriss_168(), df_fw=2, df_fh=2)
    pool = tlb.sample_valid_pool(np.random.default_rng(2), hw, layer, 40)
    assert pool is not None
    assert (pool.factors[:, tlb.L_LB, tlb.D_S] == layer.S).all()
    assert (pool.factors[:, tlb.L_LB, tlb.D_R] == layer.R).all()


@pytest.mark.parametrize("df_fw,df_fh", [(2, 1), (1, 2), (2, 2)])
def test_batched_validity_parity_on_pinned_dataflow(df_fw, df_fh):
    """The df_fw/df_fh pin branches of valid_batch agree with the scalar
    oracle (random naive mappings exercise both accept and reject)."""
    import dataclasses

    layer = PAPER_WORKLOADS["DQN-K1"]
    hw = dataclasses.replace(eyeriss_168(), df_fw=df_fw, df_fh=df_fh)
    rng = np.random.default_rng(3)
    base = eyeriss_168()
    # half sampled unaware of the pins (mostly rejected), half pin- and
    # capacity-aware (mostly accepted)
    ms = [random_mapping(rng, base, layer) for _ in range(100)]
    ms += [constrained_random_mapping(rng, hw, layer) for _ in range(100)]
    ok = tlb.valid_batch(tlb.pack(ms), hw, layer)
    scalar = [mapping_is_valid(m, hw, layer)[0] for m in ms]
    assert [bool(o) for o in ok] == scalar
    assert any(scalar) and not all(scalar)  # both branches exercised


def test_bo_batched_and_scalar_paths_agree_in_quality():
    """Both BO paths optimize: each must beat pure random warmup clearly."""
    hw = eyeriss_168()
    layer = PAPER_WORKLOADS["DQN-K2"]
    bests = {}
    for batched in (False, True):
        space = SoftwareSpace(hw, layer, batched=batched)
        r = bo_maximize(space, n_trials=40, n_warmup=15, pool_size=40, seed=0)
        assert len(r.history) == 40
        assert np.isfinite(r.best_value)
        bests[batched] = r.best_value
    # stochastic paths won't match exactly; they must land in the same regime
    assert abs(bests[True] - bests[False]) < 1.0
