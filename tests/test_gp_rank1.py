"""Incremental (rank-1) GP posteriors (ISSUE 6): `GP.append_observation`
folds one observation into the posterior by an O(n^2) Cholesky border update
with frozen hyperparameters.  The contract is exact parity with
`GP.with_data` -- the refit-from-scratch reference that rebuilds the padded
state from the same (params, data) -- to <= 1e-8, including across padding
bucket boundaries (where the append path must repad and refactorize), plus
the `fit_tol` gradient-norm early exit (0.0 = the historical fixed-length
fit, bit-for-bit)."""

import numpy as np
import pytest

from repro.core import GP, bo_maximize
from repro.core.gp import _bucket

from test_gp_bo import _QuadraticSpace


def _data(n, d=3, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.uniform(-1, 1, size=(n, d))
    y = np.sin(2 * X[:, 0]) + 0.5 * X[:, 1] ** 2 + 0.1 * X[:, 2]
    return X, y


def _grid(m=40, d=3, seed=99):
    return np.random.default_rng(seed).uniform(-1.2, 1.2, size=(m, d))


@pytest.mark.parametrize("kind", ["linear", "se"])
@pytest.mark.parametrize("noisy", [True, False])
def test_append_matches_with_data(kind, noisy):
    """Appending observations one at a time matches the frozen-hyperparameter
    rebuild on the full dataset to <= 1e-8, for both kernels and both noise
    models."""
    X, y = _data(12)
    Xn, yn = _data(3, seed=7)
    gp = GP(kind=kind, noisy=noisy).fit(X, y)
    for x, v in zip(Xn, yn):
        gp = gp.append_observation(x, float(v))
    ref = gp.with_data(np.vstack([X, Xn]), np.concatenate([y, yn]))
    Xs = _grid()
    mu_a, var_a = gp.posterior(Xs)
    mu_r, var_r = ref.posterior(Xs)
    np.testing.assert_allclose(mu_a, mu_r, atol=1e-8, rtol=1e-8)
    np.testing.assert_allclose(var_a, var_r, atol=1e-8, rtol=1e-8)


def test_append_across_bucket_boundary():
    """n = bucket size: the next append overflows the padded buffers, forcing
    the repad + refactorize path -- parity must survive the crossing."""
    n = 8
    assert _bucket(n) == n  # the fit lands exactly on a bucket boundary
    X, y = _data(n)
    Xn, yn = _data(4, seed=11)
    gp = GP().fit(X, y)
    for x, v in zip(Xn, yn):
        gp = gp.append_observation(x, float(v))
    assert gp._state[1].shape[0] == _bucket(n + 4)  # repadded to 16
    ref = gp.with_data(np.vstack([X, Xn]), np.concatenate([y, yn]))
    Xs = _grid()
    np.testing.assert_allclose(gp.posterior(Xs)[0], ref.posterior(Xs)[0],
                               atol=1e-8, rtol=1e-8)


def test_fit_discards_incremental_factor():
    """A full refit re-learns hyperparameters, so any cached incremental
    factor must be invalidated -- posteriors drop back to the factor-free
    path."""
    X, y = _data(10)
    gp = GP().fit(X, y)
    assert gp._fac is None  # strictly opt-in: fitting alone caches nothing
    gp = gp.append_observation(X[0] + 0.05, float(y[0]))
    assert gp._fac is not None
    gp.fit(X, y)
    assert gp._fac is None


def test_fit_tol_zero_matches_default_fit():
    """fit_tol=0.0 takes the fixed-length scan -- the pre-tol fit byte for
    byte: identical hyperparameters, identical posterior."""
    X, y = _data(14)
    base = GP().fit(X, y)
    tol0 = GP(fit_tol=0.0).fit(X, y)
    for k in base.params:
        np.testing.assert_array_equal(np.asarray(base.params[k]),
                                      np.asarray(tol0.params[k]))
    Xs = _grid()
    np.testing.assert_array_equal(base.posterior(Xs)[0], tol0.posterior(Xs)[0])


def test_fit_tol_early_exit_still_fits():
    """A loose tolerance stops the Adam loop early: the fit is cheaper but
    still a real fit -- the posterior mean tracks the data about as well as
    the full-length fit does."""
    X, y = _data(20)
    full = GP().fit(X, y)
    early = GP(fit_tol=0.5).fit(X, y)
    mu_f, _ = full.posterior(X)
    mu_e, _ = early.posterior(X)
    mse_f = float(np.mean((mu_f - y) ** 2))
    mse_e = float(np.mean((mu_e - y) ** 2))
    assert np.isfinite(mse_e)
    assert mse_e <= max(4 * mse_f, 0.05)


def test_bo_with_rank1_updates_runs_and_is_monotone():
    """`gp_rank1=True` keeps the surrogate's data current between aligned
    refits; the loop completes with a monotone incumbent history and finds a
    comparable optimum on the synthetic problem."""
    space = _QuadraticSpace()
    r = bo_maximize(space, n_trials=30, n_warmup=8, pool_size=40,
                    surrogate="gp_se", seed=0, gp_refit_every=4,
                    gp_rank1=True)
    assert len(r.history) == 30
    assert all(b >= a for a, b in zip(r.history, r.history[1:]))
    assert np.isfinite(r.best_value)
    assert r.best_value > -0.5  # near the quadratic's optimum, like the default


def test_bo_rank1_matches_default_at_refit_every_one():
    """With a refit every trial the incremental factor is rebuilt from a
    fresh fit each time, so gp_rank1 cannot change any selection: the runs
    are bit-identical."""
    space = _QuadraticSpace()
    a = bo_maximize(space, n_trials=25, n_warmup=8, pool_size=40,
                    surrogate="gp_se", seed=3, gp_rank1=False)
    b = bo_maximize(space, n_trials=25, n_warmup=8, pool_size=40,
                    surrogate="gp_se", seed=3, gp_rank1=True)
    assert np.array_equal(a.history, b.history)
    assert a.best_value == b.best_value
