"""Property-based parity of the JAX engine vs the NumPy engine (hypothesis).

Lives in its own module so the module-level `importorskip` only skips the
property test where hypothesis is unavailable -- the deterministic parity
suite in `test_batch_jax.py` always runs.
"""

import numpy as np

from hypothesis_support import given, settings, st

from repro.timeloop import PAPER_WORKLOADS, eyeriss_168  # noqa: E402
from repro.timeloop import batch as tlb  # noqa: E402
from repro.timeloop.mapping import (constrained_random_mapping,  # noqa: E402
                                    random_mapping)

from test_batch_jax import _assert_parity  # noqa: E402


@given(st.integers(0, 2**32 - 1), st.sampled_from(sorted(PAPER_WORKLOADS)))
@settings(max_examples=20, deadline=None)
def test_property_jax_matches_numpy_engine(seed, layer_name):
    """batch_jax == batch on randomized constrained pools across all seed
    workloads: values to 1e-6 (observed ~1e-12), validity masks and feature
    matrices exactly aligned."""
    layer = PAPER_WORKLOADS[layer_name]
    hw = eyeriss_168()
    rng = np.random.default_rng(seed)
    ms = [random_mapping(rng, hw, layer) for _ in range(4)]
    ms += [constrained_random_mapping(rng, hw, layer) for _ in range(4)]
    _assert_parity(hw, layer, tlb.pack(ms))
