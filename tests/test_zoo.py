"""Zoo workload generation: MACs cross-check vs `models/flops.py`, shape
sanity, registry resolution, the sampler divisor-cap guard, and a seeded
golden pin for the generated shapes (shape drift fails tier-1; regenerate
with `PYTHONPATH=src python tests/test_zoo.py --regen` and commit the diff
ONLY for an intended extractor change)."""

import dataclasses
import hashlib
import json
import warnings
from pathlib import Path

import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.models.flops import forward_flops
from repro.timeloop import (MODEL_LAYERS, SAMPLER_DIVISOR_CAP, divisors,
                            eyeriss_168, sampler_divisors)
from repro.timeloop.mapping import (constrained_random_mapping,
                                    mapping_is_valid,
                                    sample_constrained_batch)
from repro.timeloop.workloads import _TOKENS, ConvLayer, fc
from repro.workloads import (MACS_RTOL, ZOO_NAMES, known_workloads,
                             resolve_workload, workload_set, zoo_workload)
from repro.workloads.zoo import ZOO_SHAPE

ZOO_GOLDEN_PATH = Path(__file__).parent / "goldens" / "zoo_workloads.json"


# --- MACs cross-check vs models/flops.py ---------------------------------------

@pytest.mark.parametrize("name", ZOO_NAMES)
def test_macs_cross_check(name):
    """2 * sum(count * macs) must equal forward_flops at the zoo tile up to
    the documented non-matmul remainder (scores+PV, elementwise gates)."""
    zw = zoo_workload(name)
    assert zw.total_macs == sum(
        c * l.macs for c, l in zip(zw.counts, zw.layers))
    flops = forward_flops(get_config(zw.arch), ZOO_SHAPE)
    assert flops == zw.model_flops
    coverage = 2.0 * zw.total_macs / flops
    assert coverage == pytest.approx(zw.coverage)
    assert 1.0 - MACS_RTOL <= coverage <= 1.0 + 1e-9, (
        f"{name}: extracted MACs cover {coverage:.4f} of forward_flops")


@pytest.mark.parametrize("name", ZOO_NAMES)
def test_shape_sanity(name):
    zw = zoo_workload(name)
    assert len(zw.layers) == len(zw.counts) > 0
    names = [l.name for l in zw.layers]
    assert len(set(names)) == len(names), "duplicate layer names"
    shapes = {(l.R, l.S, l.P, l.Q, l.C, l.K, l.stride) for l in zw.layers}
    assert len(shapes) == len(zw.layers), "duplicate shapes not merged"
    for layer, count in zip(zw.layers, zw.counts):
        assert count >= 1
        assert layer.name.startswith(zw.name + "-")
        for d in ("R", "S", "P", "Q", "C", "K"):
            assert layer.dim(d) >= 1
        assert layer.stride == 1
        assert layer.macs > 0
        # GEMM encoding: token tile on P (the encoder runs a smaller tile)
        assert layer.P in (_TOKENS, max(_TOKENS // 8, 16))
        assert layer.input_extent(layer.P, layer.R) >= layer.P


# --- registry / resolution ------------------------------------------------------

def test_workload_registry_resolution():
    assert set(ZOO_NAMES) == {
        a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}
    # paper names resolve to the exact legacy lists
    assert resolve_workload("resnet") == list(MODEL_LAYERS["resnet"])
    # zoo names resolve through the generator; dashed aliases accepted
    assert workload_set("llama4_maverick_400b_a17b") \
        == resolve_workload("llama4-maverick-400b-a17b")
    known = known_workloads()
    assert "resnet" in known and "qwen3_14b" in known
    with pytest.raises(ValueError) as ei:
        resolve_workload("nope")
    msg = str(ei.value)
    assert "resnet" in msg and "qwen3_14b" in msg


def test_zoo_workload_is_cached():
    assert zoo_workload("qwen3_14b") is zoo_workload("qwen3-14b")


# --- sampler divisor-cap guard --------------------------------------------------

def test_sampler_divisors_passthrough_below_cap():
    """Every paper and zoo dim sits under the cap: the sampler ladder is the
    exact divisor tuple (so RNG streams -- and the goldens -- are
    unchanged)."""
    dims = {layer.dim(d)
            for layers in MODEL_LAYERS.values() for layer in layers
            for d in ("R", "S", "P", "Q", "C", "K")}
    for name in ZOO_NAMES:
        for layer in zoo_workload(name).layers:
            dims.update(layer.dim(d) for d in ("R", "S", "P", "Q", "C", "K"))
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # no cap warning may fire
        for n in sorted(dims):
            assert len(divisors(n)) <= SAMPLER_DIVISOR_CAP
            assert sampler_divisors(n) == divisors(n)


def test_sampler_divisors_caps_pathological_dims():
    n = 720720  # 2^4*3^2*5*7*11*13: 240 divisors
    full = divisors(n)
    assert len(full) > SAMPLER_DIVISOR_CAP
    with pytest.warns(RuntimeWarning, match="SAMPLER_DIVISOR_CAP"):
        sampler_divisors.cache_clear()
        capped = sampler_divisors(n)
    assert len(capped) <= SAMPLER_DIVISOR_CAP
    assert set(capped) <= set(full)
    assert capped[0] == 1 and capped[-1] == n
    assert list(capped) == sorted(capped)


def test_capped_dims_still_sample_valid_mappings():
    """The samplers stay structurally correct when a dim's ladder is capped:
    factor products must still equal the layer dims."""
    layer = fc("pathological", 720720, 64, _TOKENS)
    hw = eyeriss_168()
    rng = np.random.default_rng(0)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        for _ in range(5):
            m = constrained_random_mapping(rng, hw, layer)
            ok, reason = mapping_is_valid(m, hw, layer)
            assert ok or reason == "gb_capacity", reason
        factors, *_ = sample_constrained_batch(rng, hw, layer, 16)
    prods = factors.prod(axis=1)
    want = [layer.dim(d) for d in ("R", "S", "P", "Q", "C", "K")]
    assert (prods == np.array(want)[None, :]).all()


def test_conv_layer_divisors_method():
    layer = fc("x", 96, 7, _TOKENS)
    assert layer.divisors("C") == [1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 96]
    assert layer.divisors("K") == [1, 7]


# --- seeded golden pin ----------------------------------------------------------

def zoo_golden_record(name: str) -> dict:
    zw = zoo_workload(name)
    canonical = repr([(dataclasses.astuple(l), c)
                      for l, c in zip(zw.layers, zw.counts)])
    return {
        "shapes_sha256": hashlib.sha256(canonical.encode()).hexdigest(),
        "n_layers": len(zw.layers),
        "total_macs": zw.total_macs,
        "coverage": round(zw.coverage, 6),
    }


@pytest.mark.parametrize("name", ZOO_NAMES)
def test_zoo_matches_golden(name):
    goldens = json.loads(ZOO_GOLDEN_PATH.read_text())
    got = zoo_golden_record(name)
    want = goldens[name]
    assert got == want, (
        f"zoo workload drift on {name!r}:\n  got  {got}\n  want {want}\n"
        "If this PR intentionally changes the extractors, regenerate with\n"
        "  PYTHONPATH=src python tests/test_zoo.py --regen\n"
        "and commit the goldens diff; otherwise this is a regression.")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--regen", action="store_true",
                    help="rewrite tests/goldens/zoo_workloads.json")
    args = ap.parse_args()
    records = {n: zoo_golden_record(n) for n in ZOO_NAMES}
    print(json.dumps(records, indent=2))
    if args.regen:
        ZOO_GOLDEN_PATH.parent.mkdir(parents=True, exist_ok=True)
        ZOO_GOLDEN_PATH.write_text(
            json.dumps(records, indent=2, sort_keys=True) + "\n")
        print(f"wrote {ZOO_GOLDEN_PATH}")
