"""`bo_maximize_many` early-stop masks at MIXED convergence (ISSUE 5): some
runs stop early (their space proves empirically unsampleable) while others
continue.  The lockstep engine must reproduce the per-run sequential
`bo_maximize` calls run-for-run through every mixed state: a run dying during
warmup, right after warmup, mid-loop with a fitted surrogate, and runs that
never die -- with and without unknown-constraint (classifier) observations,
for both acquisitions and refit strides.

The spaces here are tiny host-side toys with a scripted sampling budget, so
the lockstep loop takes its generic (non-`LayerStackSpace`) path and every
RNG draw, refit round, and kill decision is exercised directly.
"""

import numpy as np
import pytest

from repro.core import SWSearchConfig, bo_maximize, bo_maximize_many
from repro.core.bo import BOResult, InfeasibleSpace


class ToySpace:
    """1-D batched-protocol space with a scripted sampling budget.

    After `die_after` total sampled candidates, `sample_pool` returns None --
    the space looks empirically empty from then on, which is exactly the
    mid-search state that trips a lockstep run's early-stop mask.
    `infeasible_below` makes part of the range an unknown-constraint violation
    so the feasibility classifier engages.
    """

    supports_batch = True
    feature_dim = 2
    name = "toy"

    def __init__(self, offset: float = 0.0, die_after: int | None = None,
                 infeasible_below: float | None = None):
        self.offset = offset
        self.die_after = die_after
        self.infeasible_below = infeasible_below
        self.drawn = 0

    def sample(self, rng):
        return float(rng.uniform(0.0, 1.0))

    def is_valid(self, p) -> bool:
        return True

    def features(self, p) -> np.ndarray:
        return np.array([p, (p + self.offset) ** 2], dtype=np.float64)

    def evaluate(self, p):
        if self.infeasible_below is not None and p < self.infeasible_below:
            return None, False
        return float(np.sin(3.0 * (p + self.offset)) + p), True

    # --- batched evaluation protocol ---------------------------------------------

    def sample_pool(self, rng, n: int):
        if self.die_after is not None and self.drawn + n > self.die_after:
            return None
        self.drawn += n
        return [float(x) for x in rng.uniform(0.0, 1.0, size=n)]

    def features_batch(self, pool) -> np.ndarray:
        return np.stack([self.features(p) for p in pool])

    def evaluate_batch(self, pool):
        vals = np.full(len(pool), -np.inf)
        feas = np.zeros(len(pool), dtype=bool)
        for i, p in enumerate(pool):
            v, ok = self.evaluate(p)
            feas[i] = ok
            if ok:
                vals[i] = v
        return vals, feas


def _sequential_reference(spaces, cfg, seeds, **kw):
    """Per-run `bo_maximize` with the InfeasibleSpace -> empty-result contract
    the nested driver applies (and `bo_maximize_many` promises to match)."""
    out = []
    for space, seed in zip(spaces, seeds):
        try:
            out.append(bo_maximize(space, cfg, seed=seed, **kw))
        except InfeasibleSpace:
            out.append(BOResult(None, -np.inf, [], [], []))
    return out


def _assert_runs_equal(many, ref):
    assert len(many) == len(ref)
    for k, (r, q) in enumerate(zip(many, ref)):
        assert r.best_point == q.best_point, f"run {k}"
        assert np.array_equal(r.history, q.history), f"run {k}"
        assert np.array_equal(r.values, q.values), f"run {k}"
        assert r.points == q.points, f"run {k}"
        assert r.n_infeasible == q.n_infeasible, f"run {k}"


CFG = SWSearchConfig(n_trials=12, n_warmup=4, pool_size=6)


def _mixed_spaces():
    return [
        ToySpace(0.1),                           # survives to the full budget
        ToySpace(0.4, die_after=4),              # dies at the first scored pool
        ToySpace(0.7, die_after=24),             # dies mid-loop, surrogate live
        ToySpace(0.9, die_after=2),              # dies during warmup
        ToySpace(0.2, infeasible_below=0.55),    # classifier engaged, survives
    ]


@pytest.mark.parametrize("gp_refit_every", [1, 3])
@pytest.mark.parametrize("acquisition", [
    "lcb", pytest.param("ei", marks=pytest.mark.slow)])
def test_mixed_convergence_matches_per_run_sequential(acquisition,
                                                      gp_refit_every):
    """Lockstep histories/points/values equal the per-run sequential searches
    through every early-stop state, including runs that die while OTHERS keep
    scoring (the masks must neither leak dead runs into scoring nor perturb
    the survivors' RNG streams or refit cadence)."""
    cfg = SWSearchConfig(n_trials=12, n_warmup=4, pool_size=6,
                         acquisition=acquisition)
    seeds = [3, 5, 7, 9, 11]
    many = bo_maximize_many(_mixed_spaces(), cfg, seed=seeds,
                            gp_refit_every=gp_refit_every)
    ref = _sequential_reference(_mixed_spaces(), cfg, seeds,
                                gp_refit_every=gp_refit_every)
    _assert_runs_equal(many, ref)
    # the scripted deaths actually produced the mixed state this test is about
    assert many[0].best_point is not None
    assert many[1].best_point is None and many[3].best_point is None
    # run 2 died mid-loop WITH observations in hand; like the sequential
    # InfeasibleSpace contract, the partial history is discarded
    assert many[2].best_point is None and len(many[2].history) == 0


def test_all_runs_dying_terminates_early():
    spaces = [ToySpace(0.1, die_after=10), ToySpace(0.5, die_after=12)]
    many = bo_maximize_many(spaces, CFG, seed=[1, 2])
    ref = _sequential_reference([ToySpace(0.1, die_after=10),
                                 ToySpace(0.5, die_after=12)], CFG, [1, 2])
    _assert_runs_equal(many, ref)
    assert all(r.best_point is None for r in many)


def test_no_warmup_mixed_convergence():
    """n_warmup=0: every run starts from single-candidate sampling; deaths in
    that phase must match the sequential InfeasibleSpace outcome too."""
    cfg = SWSearchConfig(n_trials=10, n_warmup=0, pool_size=5)
    def build():
        return [ToySpace(0.3), ToySpace(0.6, die_after=3),
                ToySpace(0.8, die_after=15)]
    seeds = [2, 4, 6]
    many = bo_maximize_many(build(), cfg, seed=seeds)
    ref = _sequential_reference(build(), cfg, seeds)
    _assert_runs_equal(many, ref)


def test_death_does_not_disturb_survivor_rng_streams():
    """A survivor run must draw exactly the same candidate stream whether its
    lockstep peers die or not."""
    solo = bo_maximize(ToySpace(0.1), CFG, seed=3)
    with_dying_peers = bo_maximize_many(
        [ToySpace(0.1), ToySpace(0.9, die_after=2), ToySpace(0.4, die_after=4)],
        CFG, seed=[3, 9, 5])[0]
    assert solo.points == with_dying_peers.points
    assert np.array_equal(solo.history, with_dying_peers.history)
