"""GP regression/classification numerics and BO behaviour."""

import numpy as np
import pytest

from repro.core import (GP, GPClassifier, RandomForestSurrogate, bo_maximize,
                        expected_improvement, lcb, random_search)
from repro.core.trees import GradientBoostedTrees


def test_gp_interpolates_noiseless():
    rng = np.random.default_rng(0)
    X = rng.uniform(-2, 2, size=(24, 3))
    y = np.sin(X[:, 0]) + 0.5 * X[:, 1] ** 2
    gp = GP(kind="se", noisy=False).fit(X, y)
    mu, var = gp.posterior(X)
    assert np.max(np.abs(mu - y)) < 1e-2
    assert np.max(var) < 1e-2


def test_gp_linear_recovers_linear_fn():
    rng = np.random.default_rng(1)
    X = rng.normal(size=(40, 5))
    w = np.array([1.0, -2.0, 0.5, 0.0, 3.0])
    y = X @ w
    gp = GP(kind="linear", noisy=False).fit(X, y)
    Xs = rng.normal(size=(20, 5))
    mu, _ = gp.posterior(Xs)
    assert np.corrcoef(mu, Xs @ w)[0, 1] > 0.999


def test_gp_posterior_variance_grows_off_data():
    rng = np.random.default_rng(2)
    X = rng.uniform(0, 1, size=(16, 2))
    y = X.sum(-1)
    gp = GP(kind="se", noisy=True).fit(X, y)
    _, var_near = gp.posterior(X)
    _, var_far = gp.posterior(np.full((4, 2), 10.0))
    assert var_far.mean() > var_near.mean() * 5


def test_classifier_separates():
    rng = np.random.default_rng(3)
    X = rng.normal(size=(60, 2))
    feas = X[:, 0] > 0
    clf = GPClassifier().fit(X, feas)
    p_pos = clf.prob_feasible(np.array([[2.0, 0.0]]))
    p_neg = clf.prob_feasible(np.array([[-2.0, 0.0]]))
    assert p_pos[0] > 0.7 > 0.3 > p_neg[0]


def test_acquisitions():
    mu = np.array([0.0, 1.0])
    var = np.array([1.0, 1e-8])
    ei = expected_improvement(mu, var, best=0.5)
    assert ei[0] > 0  # uncertainty gives the worse mean some value
    assert lcb(mu, var, 2.0)[0] == pytest.approx(2.0)


def test_tree_surrogates_fit():
    rng = np.random.default_rng(4)
    X = rng.uniform(-1, 1, size=(80, 4))
    y = np.where(X[:, 0] > 0, 1.0, -1.0) + 0.1 * X[:, 1]
    rf = RandomForestSurrogate(n_trees=10, seed=0).fit(X, y)
    mu, var = rf.posterior(X)
    assert np.mean((mu - y) ** 2) < 0.2
    gbt = GradientBoostedTrees(n_rounds=20, seed=0).fit(X, y)
    assert np.mean((gbt.predict(X) - y) ** 2) < 0.1


class _QuadraticSpace:
    """Synthetic constrained maximization problem: maximize -(x-c)^2 subject to
    a known ball constraint (input) and an unknown half-space constraint."""

    name = "quad"
    feature_dim = 4

    def __init__(self, seed=0):
        self.c = np.array([0.3, -0.2, 0.5, 0.1])

    def sample(self, rng):
        return rng.uniform(-1, 1, 4)

    def is_valid(self, x):
        return float(np.linalg.norm(x)) <= 1.2  # known constraint

    def features(self, x):
        return np.asarray(x)

    def evaluate(self, x):
        if x[0] + x[1] < -0.3:  # unknown constraint
            return None, False
        return -float(np.sum((x - self.c) ** 2)), True


def test_bo_beats_random_on_synthetic():
    wins = 0
    for seed in range(3):
        space = _QuadraticSpace()
        r_bo = bo_maximize(space, n_trials=40, n_warmup=10, pool_size=60, surrogate="gp_se", seed=seed)
        r_rs = random_search(space, n_trials=40, seed=seed)
        wins += int(r_bo.best_value >= r_rs.best_value)
    assert wins >= 2


def test_bo_records_unknown_constraint_violations():
    space = _QuadraticSpace()
    r = bo_maximize(space, n_trials=30, n_warmup=10, pool_size=40, surrogate="gp_se", seed=0)
    assert r.n_infeasible > 0          # it must have bumped into the hidden wall
    assert r.best_point is not None
    assert len(r.history) == 30
    assert all(b >= a for a, b in zip(r.history, r.history[1:]))  # monotone