"""Bound-gated pruning (ISSUE 6): `prune="safe"` must not change WHAT the
nested search finds -- the gate only swaps provably-doomed inner searches for
censored bound certificates.  Pinned at three levels:

  * golden: a safe run reproduces the same checked-in golden record that
    `tests/test_golden.py` pins for the default (`prune="off"`) path, on all
    four seed workloads -- bit-identical designs, EDPs and trial counts;
  * unit: the gate closure's contract -- fires only past the incumbent-scaled
    threshold, censored utilities never beat the incumbent's true utility,
    fully-cached probes and warmup (no incumbent) always pass, the margin
    scales under "aggressive", and the stats counters track it;
  * e2e invariants on runs where the gate actually fires: the reported winner
    is always a TRUE evaluation (its per-layer mappings are real and re-sum
    to the reported EDP) and `best_value` matches it -- a censored
    observation can never be reported as the best.
"""

import dataclasses
import hashlib
import json

import numpy as np
import pytest

from repro.core import (CodesignConfig, CodesignEngine, EngineConfig,
                        HWSearchConfig, SWSearchConfig)
from repro.timeloop import MODEL_LAYERS, evaluate, eyeriss_168
from repro.timeloop.bounds import lower_bound

from test_golden import GOLDEN_PATH, MODELS, _canonical, _config


def _prune_config(model: str, prune: str, **hw_over) -> CodesignConfig:
    cfg = _config(model)
    return dataclasses.replace(
        cfg, hw=dataclasses.replace(cfg.hw, prune=prune, **hw_over))


# --- golden parity ----------------------------------------------------------------


@pytest.mark.e2e
@pytest.mark.parametrize("model", MODELS)
def test_safe_prune_matches_golden(model):
    """`prune="safe"` reproduces the exact golden record the default path is
    pinned to: same winning design hash, same best EDP, same trial count."""
    result = CodesignEngine(_prune_config(model, "safe")).run(
        MODEL_LAYERS[model])
    got = {
        "design_sha256": hashlib.sha256(
            _canonical(result).encode()).hexdigest(),
        "best_log10_edp": round(float(np.log10(result.best_model_edp)), 6),
        "n_trials": len(result.hw_result.history),
    }
    goldens = json.loads(GOLDEN_PATH.read_text())
    assert got == goldens[model], (
        f"prune='safe' diverged from the golden (= prune='off') on {model!r}")


@pytest.mark.e2e
def test_off_vs_safe_full_equality():
    """Beyond the golden hash: the full outer history, points and best value
    are bit-equal off vs safe at the golden budgets."""
    layers = MODEL_LAYERS["dqn"]
    a = CodesignEngine(_prune_config("dqn", "off")).run(layers)
    b = CodesignEngine(_prune_config("dqn", "safe")).run(layers)
    assert a.best_hw == b.best_hw
    assert a.best_model_edp == b.best_model_edp
    assert a.best_mappings == b.best_mappings
    assert np.array_equal(a.hw_result.history, b.hw_result.history)
    assert a.hw_result.points == b.hw_result.points


# --- gate unit contract -----------------------------------------------------------


def _gate_engine(prune="safe", prune_margin=1.0) -> CodesignEngine:
    eng = CodesignEngine(CodesignConfig(
        hw=HWSearchConfig(prune=prune, prune_margin=prune_margin),
        engine=EngineConfig(backend="numpy")))
    eng._layers = list(MODEL_LAYERS["dqn"])
    eng.stats = {"spec_evaluated": 0, "spec_hits": 0, "prune_considered": 0,
                 "prune_pruned": 0, "probes_gated": 0}
    return eng


def _bound_sum(eng, hw) -> float:
    return sum(lower_bound(hw, layer) for layer in eng._layers)


def test_gate_off_is_none():
    eng = _gate_engine("off")
    assert eng._make_probe_gate({"edp": 1.0}) is None
    assert eng._make_prune_fn({"edp": 1.0}) is None
    assert not eng.probe_doomed(eyeriss_168())  # no gate installed


def test_gate_censors_doomed_probe_and_counts():
    eng = _gate_engine("safe")
    hw = eyeriss_168()
    s = _bound_sum(eng, hw)
    best = {"edp": s / 2}  # incumbent strictly beats the probe's bound
    gate = eng._gate = eng._make_probe_gate(best)
    censored = gate(hw)
    assert censored == -float(np.log10(s))
    # the censored utility can never displace the incumbent's true utility
    assert censored < -np.log10(best["edp"])
    assert eng.stats["probes_gated"] == 1
    # count=False (the fan-out filter's path) reports without counting
    assert gate(hw, count=False) == censored
    assert eng.stats["probes_gated"] == 1
    assert eng.probe_doomed(hw)


def test_gate_passes_warmup_viable_and_cached():
    eng = _gate_engine("safe")
    hw = eyeriss_168()
    s = _bound_sum(eng, hw)
    # warmup: no incumbent yet
    assert eng._make_probe_gate({"edp": np.inf})(hw) is None
    # viable: the bound does not rule the probe out
    assert eng._make_probe_gate({"edp": s * 2})(hw) is None
    # fully cached: the search is already paid for, use the true value
    gate = eng._make_probe_gate({"edp": s / 2})
    for layer in eng._layers:
        eng.cache[(hw, layer)] = (None, float("inf"))
    assert gate(hw) is None
    assert eng.stats["probes_gated"] == 0


def test_aggressive_margin_scales_gate_threshold():
    """A probe gated under "safe" (bound > incumbent) survives an
    "aggressive" margin that moves the threshold past its bound."""
    hw = eyeriss_168()
    safe = _gate_engine("safe")
    s = _bound_sum(safe, hw)
    best = {"edp": s / 2}  # bound = 2x incumbent
    assert safe._make_probe_gate(best)(hw) is not None
    loose = _gate_engine("aggressive", prune_margin=4.0)  # threshold 2x bound
    assert loose._make_probe_gate(best)(hw) is None
    tight = _gate_engine("aggressive", prune_margin=0.25)
    assert tight._make_probe_gate(best)(hw) is not None


def test_prune_fn_filters_pool_keeps_lowest_bound():
    """The aggressive pool hook drops bound-dominated candidates, never
    empties the pool, and tracks the counters."""
    eng = _gate_engine("aggressive", prune_margin=1.0)
    rng = np.random.default_rng(0)
    from repro.core.hwspace import HardwareSpace
    pool = HardwareSpace(num_pes=168).sample_pool(rng, 6)
    prune = eng._make_prune_fn({"edp": np.inf})
    assert prune(pool) == pool  # warmup: nothing to bound against
    assert eng.stats["prune_considered"] == 0
    sums = [_bound_sum(eng, hw) for hw in pool]
    # incumbent below every bound: everything is doomed, the guard keeps
    # exactly the lowest-bound candidate
    prune = eng._make_prune_fn({"edp": min(sums) / 2})
    kept = prune(pool)
    assert kept == [pool[int(np.argmin(sums))]]
    assert eng.stats["prune_considered"] == len(pool)
    assert eng.stats["prune_pruned"] == len(pool) - 1


# --- e2e invariants when the gate fires -------------------------------------------


def _run_gated(prune: str, **hw_over):
    cfg = CodesignConfig(
        sw=SWSearchConfig(n_trials=10, n_warmup=5, pool_size=15),
        hw=HWSearchConfig(n_trials=8, n_warmup=2, pool_size=12,
                          prune=prune, **hw_over),
        engine=EngineConfig(backend="numpy"),
        seed=0)
    eng = CodesignEngine(cfg)
    return eng.run(MODEL_LAYERS["dqn"])


def test_aggressive_gate_fires_and_winner_is_true_evaluation():
    """With a sub-1 margin the gate censors aggressively -- yet the reported
    winner is always a true evaluation: real per-layer mappings whose scalar
    re-evaluation sums to the reported EDP, and `best_value` matches it
    (censored observations are clamped below every true incumbent)."""
    res = _run_gated("aggressive", prune_margin=1e-3)
    assert res.stats["probes_gated"] > 0
    assert res.stats["pruned_fraction"] > 0  # the pool hook engaged too
    assert np.isfinite(res.best_model_edp)
    total = 0.0
    for layer in MODEL_LAYERS["dqn"]:
        m = res.best_mappings[layer.name]
        ev = evaluate(res.best_hw, m, layer)
        assert ev.valid
        total += ev.edp
    assert total == pytest.approx(res.best_model_edp, rel=1e-12)
    assert res.hw_result.best_value == pytest.approx(
        -np.log10(res.best_model_edp), rel=1e-12)
