"""Full nested HW/SW co-design on the DQN workload (the paper's best case:
40.2% EDP improvement over Eyeriss), on the typed config API.

    PYTHONPATH=src python examples/codesign_dqn.py [--paper | --tiny]
        [--strategy auto|sequential|layer_batched|probe_fanout|speculative]
        [--hw-refit-every N] [--prune off|safe|aggressive]
        [--backend numpy|jax] [--save-config cfg.json]

`--strategy speculative` pairs best with `--hw-refit-every 4`: the outer loop
then consumes one frozen q-batch per refit window and the speculative fan-out
evaluates each window's batch as one stacked program (cache hit-rate is
printed from the result record).

`--save-config` writes the exact `CodesignConfig` that ran as JSON; feed it
back through `python -m benchmarks.run --config cfg.json` (or
`CodesignConfig.from_json`) to reproduce the search.
"""

import argparse
import dataclasses

from repro.core import (BACKENDS, PRUNE_MODES, STRATEGIES, CodesignConfig,
                        CodesignEngine, EngineConfig, HWSearchConfig,
                        SWSearchConfig)
from repro.timeloop import MODEL_LAYERS, eyeriss_baseline_edp


def build_config(args) -> CodesignConfig:
    if args.paper:  # 50 HW x 250 SW trials (paper §4.1)
        sw = SWSearchConfig()                      # 250 / 30 / 150
        hw = HWSearchConfig()                      # 50 / 5 / 150
    elif args.tiny:  # CI smoke budgets: seconds, exercises every layer
        sw = SWSearchConfig(n_trials=10, n_warmup=5, pool_size=16)
        hw = HWSearchConfig(n_trials=2, n_warmup=2, pool_size=16)
    else:
        sw = SWSearchConfig(n_trials=60, n_warmup=20, pool_size=60)
        hw = HWSearchConfig(n_trials=12, pool_size=60)
    hw = dataclasses.replace(hw, prune=args.prune)
    return CodesignConfig(
        sw=sw, hw=hw,
        engine=EngineConfig(backend=args.backend, strategy=args.strategy,
                            hw_gp_refit_every=args.hw_refit_every),
        seed=0, verbose=not args.tiny,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true", help="50 HW x 250 SW trials")
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-test budgets (CI)")
    ap.add_argument("--backend", default=None, choices=BACKENDS)
    ap.add_argument("--strategy", default="auto", choices=STRATEGIES)
    ap.add_argument("--hw-refit-every", type=int, default=1,
                    help="outer-loop GP refit stride; >1 batches the outer "
                         "acquisition into frozen q-batch windows (pairs "
                         "with --strategy speculative)")
    ap.add_argument("--prune", default="off", choices=PRUNE_MODES,
                    help="bound-gated pruning of doomed outer probes "
                         "(timeloop.bounds): 'safe' never changes the result")
    ap.add_argument("--save-config", default=None, metavar="PATH",
                    help="write the CodesignConfig that ran as JSON")
    args = ap.parse_args()

    layers = MODEL_LAYERS["dqn"]
    base = eyeriss_baseline_edp(layers, num_pes=168, budget=4000)
    base_total = sum(base.values())
    print(f"Eyeriss baseline: model EDP {base_total:.3e}")
    for k, v in base.items():
        print(f"  {k}: {v:.3e}")

    config = build_config(args)
    # The config is one serializable object: JSON round-trip is exact.
    assert CodesignConfig.from_json(config.to_json()) == config
    if args.save_config:
        with open(args.save_config, "w") as f:
            f.write(config.to_json())
        print(f"wrote {args.save_config}")

    engine = CodesignEngine(config)
    print(f"search: {config.hw.n_trials} HW x {config.sw.n_trials} SW trials, "
          f"backend={engine.backend}, strategy={engine.strategy_name}")
    res = engine.run(layers)

    print(f"\nco-designed: model EDP {res.best_model_edp:.3e} "
          f"({(1 - res.best_model_edp / base_total) * 100:.1f}% better than Eyeriss)")
    if res.stats and res.stats["spec_evaluated"]:
        print(f"speculation: {res.stats['spec_evaluated']} probes evaluated "
              f"ahead of time, {res.stats['spec_hits']} consumed "
              f"(hit rate {res.stats['spec_hit_rate']:.0%})")
    if res.stats and config.hw.prune != "off":
        print(f"pruning: {res.stats['probes_gated']} probe(s) bound-gated, "
              f"{res.stats['prune_pruned']} pool candidate(s) removed "
              f"(pruned fraction {res.stats['pruned_fraction']:.0%})")
    hw = res.best_hw
    print(f"best hardware: PE array {hw.pe_mesh_x}x{hw.pe_mesh_y}, "
          f"LB split I/W/O = {hw.lb_input}/{hw.lb_weight}/{hw.lb_output}, "
          f"GB {hw.gb_instances} instance(s) "
          f"({hw.gb_mesh_x}x{hw.gb_mesh_y}, block {hw.gb_block}, "
          f"cluster {hw.gb_cluster}), dataflow fw={hw.df_fw} fh={hw.df_fh}")
    for name, edp in res.layer_edps.items():
        print(f"  {name}: {edp:.3e}  (eyeriss {base[name]:.3e})")


if __name__ == "__main__":
    main()
