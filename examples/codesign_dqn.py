"""Full nested HW/SW co-design on the DQN workload (the paper's best case:
40.2% EDP improvement over Eyeriss).

    PYTHONPATH=src python examples/codesign_dqn.py [--paper]
"""

import argparse

from repro.core import codesign
from repro.timeloop import MODEL_LAYERS, eyeriss_baseline_edp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true", help="50 HW x 250 SW trials")
    args = ap.parse_args()

    layers = MODEL_LAYERS["dqn"]
    base = eyeriss_baseline_edp(layers, num_pes=168, budget=4000)
    base_total = sum(base.values())
    print(f"Eyeriss baseline: model EDP {base_total:.3e}")
    for k, v in base.items():
        print(f"  {k}: {v:.3e}")

    kwargs = (dict(n_hw_trials=50, n_sw_trials=250, n_sw_warmup=30,
                   sw_pool=150, hw_pool=150)
              if args.paper else
              dict(n_hw_trials=12, n_sw_trials=60, n_sw_warmup=20,
                   sw_pool=60, hw_pool=60))
    res = codesign(layers, num_pes=168, seed=0, verbose=True, **kwargs)

    print(f"\nco-designed: model EDP {res.best_model_edp:.3e} "
          f"({(1 - res.best_model_edp / base_total) * 100:.1f}% better than Eyeriss)")
    hw = res.best_hw
    print(f"best hardware: PE array {hw.pe_mesh_x}x{hw.pe_mesh_y}, "
          f"LB split I/W/O = {hw.lb_input}/{hw.lb_weight}/{hw.lb_output}, "
          f"GB {hw.gb_instances} instance(s) "
          f"({hw.gb_mesh_x}x{hw.gb_mesh_y}, block {hw.gb_block}, "
          f"cluster {hw.gb_cluster}), dataflow fw={hw.df_fw} fh={hw.df_fh}")
    for name, edp in res.layer_edps.items():
        print(f"  {name}: {edp:.3e}  (eyeriss {base[name]:.3e})")


if __name__ == "__main__":
    main()
