"""Co-design as a service: many tenants' nested searches, one fused engine.

    PYTHONPATH=src python examples/codesign_service.py [--tiny] [--warm-start]
        [--store-dir DIR] [--max-slots N] [--no-fuse]
        [--backend numpy|jax] [--executor inline|process] [--workers N]

Submits a mixed batch of co-design requests (DQN + MLP workloads, one of them
round-tripped through the JSON queue surface), serves them concurrently --
each scheduler tick fuses every live session's pending inner software
searches into ONE cross-request stacked dispatch -- and prints per-request
results with latency/throughput and cache/store accounting.  Every result is
bit-identical to running that request standalone through
`CodesignEngine(config).run(layers)`.

With `--store-dir`, finished (hw, layer) searches persist in a
content-addressed design store and the batch is resubmitted once more: the
warm pass answers every request from disk without a single inner search.

With `--warm-start`, the service additionally keeps a cross-run trial history
and runs a third pass with `HWSearchConfig.warm_start` on: each request's
outer GP starts from the cold pass's recorded trials, exact store misses fall
back to approximate (nearest stored hardware) warm starts, and the printout
adds the consumed prior rows + warm hits plus a per-request cold-vs-warm
incumbent comparison.  Priors reshape the outer acquisition, so warm results
can differ from cold; what stays exact is the replay contract (pass 2 is
asserted bit-identical to pass 1) and that approximate hits always carry
exactly evaluated EDPs.
"""

import argparse
import dataclasses
import shutil
import tempfile

from repro.core import (BACKENDS, EXECUTOR_KINDS, CodesignConfig,
                        EngineConfig, ExecutorConfig, HWSearchConfig,
                        ServiceConfig, SWSearchConfig)
from repro.service import CodesignService, ServiceRequest, make_executor
from repro.timeloop import MODEL_LAYERS


def build_requests(args) -> list[ServiceRequest]:
    if args.tiny:  # CI smoke budgets: seconds, exercises every layer
        sw = SWSearchConfig(n_trials=10, n_warmup=5, pool_size=16)
        hw = HWSearchConfig(n_trials=2, n_warmup=2, pool_size=16)
    else:
        sw = SWSearchConfig(n_trials=25, n_warmup=8, pool_size=60)
        hw = HWSearchConfig(n_trials=6, pool_size=60)
    reqs = []
    for i, model in enumerate(("dqn", "mlp", "dqn", "mlp")):
        cfg = CodesignConfig(sw=sw, hw=hw, seed=i,
                             engine=EngineConfig(backend=args.backend))
        reqs.append(ServiceRequest(layers=tuple(MODEL_LAYERS[model]),
                                   config=cfg, rid=f"{model}-{i}"))
    # The queue surface is JSON: a request round-trips exactly.
    assert ServiceRequest.from_json(reqs[0].to_json()) == reqs[0]
    return reqs


def serve(requests, service_config, executor=None, baseline=None) -> dict:
    svc = CodesignService(service_config, executor=executor)
    rids = [svc.submit(r) for r in requests]
    responses = svc.run()
    for rid in rids:
        resp = responses[rid]
        stats = resp.result.stats
        transfer = (f"  prior {stats['prior_rows']}  "
                    f"warm {stats['warm_hits']}"
                    if stats.get("prior_rows") or stats.get("warm_hits")
                    else "")
        if baseline is not None:
            cold = baseline[rid].result.best_model_edp
            warm = resp.result.best_model_edp
            transfer += ("  vs cold: " + ("better" if warm < cold else
                                          "equal" if warm == cold else
                                          "worse"))
        print(f"  {rid}: model EDP {resp.result.best_model_edp:.3e}  "
              f"latency {resp.latency_s:.2f}s  ticks {resp.ticks}  "
              f"store {stats['store_hits']}h/{stats['store_misses']}m  "
              f"cache {stats['cache_hits']}h/{stats['cache_misses']}m"
              f"{transfer}")
    total = max(r.latency_s for r in responses.values())
    print(f"  throughput: {len(rids)} requests in {total:.2f}s "
          f"({len(rids) / total * 60:.1f} req/min), "
          f"{svc.stats['fused_dispatches']} fused dispatches over "
          f"{svc.stats['ticks']} ticks, "
          f"{svc.stats['deduped_items']} searches deduped across requests")
    return responses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-test budgets (CI)")
    ap.add_argument("--backend", default=None, choices=BACKENDS)
    ap.add_argument("--max-slots", type=int, default=4,
                    help="concurrent search sessions per tick")
    ap.add_argument("--no-fuse", action="store_true",
                    help="one dispatch per request per tick (ablation; "
                         "results are identical either way)")
    ap.add_argument("--store-dir", default=None, metavar="DIR",
                    help="persistent design-store directory (default: a "
                         "temporary one, removed on exit)")
    ap.add_argument("--warm-start", action="store_true",
                    help="keep a cross-run trial history and run a third "
                         "pass with hw.warm_start on: outer GPs seeded from "
                         "the cold pass's recorded trials, approximate "
                         "(nearest stored hardware) warm starts on exact "
                         "store misses")
    ap.add_argument("--executor", default="inline", choices=EXECUTOR_KINDS,
                    help="where fused dispatches run: in-process (inline) or "
                         "on a worker-process pool (results are bit-identical "
                         "either way)")
    ap.add_argument("--workers", type=int, default=0,
                    help="process-executor pool width (0 = one per core, "
                         "capped at 4)")
    args = ap.parse_args()

    store_dir = args.store_dir or tempfile.mkdtemp(prefix="design_store_")
    history_dir = (tempfile.mkdtemp(prefix="trial_history_")
                   if args.warm_start else None)
    sc = ServiceConfig(max_slots=args.max_slots, fuse=not args.no_fuse,
                       store_dir=store_dir, history_dir=history_dir,
                       executor=ExecutorConfig(kind=args.executor,
                                               n_workers=args.workers))
    requests = build_requests(args)

    # One shared executor across both passes, so the process pool's spawn +
    # import cost is paid once (exactly how a long-lived service would run).
    executor = make_executor(sc.executor)
    try:
        print(f"cold pass: {len(requests)} concurrent requests, "
              f"max_slots={sc.max_slots}, fuse={sc.fuse}, "
              f"executor={executor.kind}, store={store_dir}")
        cold = serve(requests, sc, executor)

        print("warm pass: same workload resubmitted -- every (hw, layer) "
              "search replays from the design store, zero inner searches")
        replay = serve(requests, sc, executor)
        assert all(replay[rid].result.best_model_edp
                   == cold[rid].result.best_model_edp
                   for rid in cold), "store replay changed a result"

        if args.warm_start:
            print("warm-start pass: hw.warm_start on -- outer GPs seeded "
                  "from the recorded trial history, approximate warm starts "
                  "on exact store misses")
            warm_requests = [
                dataclasses.replace(
                    r, config=dataclasses.replace(
                        r.config, hw=dataclasses.replace(
                            r.config.hw, warm_start=True)))
                for r in requests]
            serve(warm_requests, sc, executor, baseline=cold)
    finally:
        executor.close()
        if args.store_dir is None:
            shutil.rmtree(store_dir, ignore_errors=True)
        if history_dir is not None:
            shutil.rmtree(history_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
