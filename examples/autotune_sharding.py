"""Beyond-paper: the constrained-BO engine autotuning THIS framework's own
sharding/remat/block configuration, with `lower().compile()` + roofline as the
expensive black-box simulator (see DESIGN.md and EXPERIMENTS.md §Perf).

    PYTHONPATH=src python examples/autotune_sharding.py \
        --arch smollm-360m --shape train_4k --trials 8
"""

# The dry-run needs the 512 placeholder devices BEFORE any jax import.
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--trials", type=int, default=8)
    ap.add_argument("--warmup", type=int, default=3)
    args = ap.parse_args()

    from repro.configs.base import SHAPES, get_config
    from repro.core.autotune import TuneConfig, TuneSpace, autotune

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    print(f"autotuning {args.arch} x {args.shape}: mesh split x fsdp x remat x "
          f"flash blocks ({args.trials} compiles, each is the expensive sample)")

    space = TuneSpace(cfg, shape)
    base = TuneConfig()  # the framework's hand-written default
    base_util, base_ok = space.evaluate(base)
    base_step = space.last_record["roofline"]["step_time_s"] if base_ok else None
    print(f"baseline {base}: step {base_step:.4f}s" if base_ok else "baseline infeasible")

    best, result = autotune(cfg, shape, n_trials=args.trials,
                            n_warmup=args.warmup, pool_size=24, seed=0)
    space.evaluate(best)
    rec = space.last_record
    t = rec["roofline"]
    print(f"\nbest tune: {best}")
    print(f"  step {t['step_time_s']:.4f}s (bound: {t['bound']}) "
          f"mem {rec['memory']['total_gib_per_dev']} GiB/dev "
          f"MFU~{rec['mfu_estimate']:.2%}")
    if base_ok:
        print(f"  speedup over hand-written default: "
              f"{base_step / t['step_time_s']:.2f}x")
    print(f"  infeasible compiles hit (unknown constraints): {result.n_infeasible}")


if __name__ == "__main__":
    main()
