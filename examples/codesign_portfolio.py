"""Portfolio co-design: ONE chip scored against a weighted mix of zoo models.

    PYTHONPATH=src python examples/codesign_portfolio.py [--tiny]
        [--workloads NAME,NAME,...] [--weights W,W,...]
        [--backend numpy|jax] [--specialists] [--service]

Builds a `PortfolioConfig` over workload-zoo models (modern LLM configs turned
into deduped ConvLayer sets, MACs cross-checked against `models/flops.py`),
runs the portfolio outer search -- every trial fans the union of all members'
layers into one stacked inner dispatch and scores the chip by the weighted
geomean of per-member EDPs -- and prints the winning hardware, the per-member
EDP split, and the Pareto front of non-dominated probes.

`--specialists` additionally runs one standalone search per member at the same
budgets and prints the specialist-vs-portfolio EDP table (the generalization
gap of one-chip-per-model vs one-chip-for-all).  `--service` round-trips the
same portfolio through the co-design service's JSON queue surface and asserts
the result is identical.
"""

import argparse
import json

from repro.core import (BACKENDS, CodesignConfig, CodesignEngine,
                        EngineConfig, HWSearchConfig, ServiceConfig,
                        SWSearchConfig)
from repro.service import CodesignService, ServiceRequest
from repro.workloads import (PortfolioConfig, portfolio_codesign,
                             resolve_workload)


def build_config(args) -> CodesignConfig:
    if args.tiny:  # CI smoke budgets: seconds, exercises every layer
        sw = SWSearchConfig(n_trials=10, n_warmup=5, pool_size=16)
        hw = HWSearchConfig(n_trials=2, n_warmup=2, pool_size=16)
    else:
        sw = SWSearchConfig(n_trials=25, n_warmup=8, pool_size=60)
        hw = HWSearchConfig(n_trials=6, pool_size=60)
    return CodesignConfig(sw=sw, hw=hw, seed=args.seed,
                          engine=EngineConfig(backend=args.backend))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-test budgets (CI)")
    ap.add_argument("--workloads",
                    default="smollm_360m,qwen3_14b,moonshot_v1_16b_a3b",
                    help="comma-separated zoo/paper workload names")
    ap.add_argument("--weights", default=None,
                    help="comma-separated member weights (default: uniform)")
    ap.add_argument("--backend", default=None, choices=BACKENDS)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--specialists", action="store_true",
                    help="also run per-member standalone searches and print "
                         "the specialist-vs-portfolio EDP table")
    ap.add_argument("--service", action="store_true",
                    help="round-trip the portfolio through the co-design "
                         "service JSON surface and check parity")
    args = ap.parse_args()

    workloads = tuple(w.strip() for w in args.workloads.split(","))
    weights = (tuple(float(w) for w in args.weights.split(","))
               if args.weights else ())
    pf = PortfolioConfig(workloads=workloads, weights=weights)
    # The portfolio spec is JSON all the way down.
    assert PortfolioConfig.from_json(pf.to_json()) == pf
    cfg = build_config(args)

    n_layers = sum(len(resolve_workload(w)) for w in workloads)
    print(f"portfolio: {', '.join(workloads)}  "
          f"weights={[round(w, 3) for w in pf.normalized_weights()]}  "
          f"({n_layers} stacked layers per outer trial)")
    res = portfolio_codesign(pf, cfg)
    edps = res.stats["portfolio_member_edps"]
    print(f"  best chip: {res.best_hw}")
    print(f"  weighted-geomean EDP {res.best_model_edp:.3e}")
    for name in workloads:
        print(f"    {name}: EDP {edps[name]:.3e}")
    front = res.stats["portfolio_pareto"]
    print(f"  pareto front: {len(front)} non-dominated probes")
    for p in front[:5]:
        cells = "  ".join(f"{m}={e:.2e}" for m, e in p["member_edps"].items())
        print(f"    {cells}")

    if args.specialists:
        print("specialists: one standalone search per member, same budgets")
        table = {}
        for name in workloads:
            r = CodesignEngine(cfg).run(list(resolve_workload(name)))
            table[name] = r.best_model_edp
            own = edps[name] / r.best_model_edp
            print(f"    {name}: specialist EDP {r.best_model_edp:.3e}  "
                  f"(portfolio chip is {own:.2f}x on this model)")

    if args.service:
        print("service: same portfolio through the JSON queue surface")
        svc = CodesignService(ServiceConfig())
        req = ServiceRequest.from_dict(json.loads(json.dumps(
            {"portfolio": pf.to_dict(), "config": cfg.to_dict(),
             "rid": "portfolio-0"})))
        svc.submit(req)
        resp = svc.run()["portfolio-0"]
        svc.close()
        assert resp.result.best_hw == res.best_hw
        assert resp.result.stats["portfolio_member_edps"] == edps
        print(f"    parity OK: service EDP {resp.result.best_model_edp:.3e} "
              f"in {resp.latency_s:.2f}s")


if __name__ == "__main__":
    main()
