"""Quickstart: the paper's technique in ~40 lines.

Optimize the software mapping of one ResNet layer on the Eyeriss accelerator
with constrained Bayesian optimization, and compare against constrained random
search.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import SoftwareSpace, bo_maximize, random_search
from repro.timeloop import PAPER_WORKLOADS, evaluate, eyeriss_168


def main():
    hw = eyeriss_168()
    layer = PAPER_WORKLOADS["ResNet-K2"]
    space = SoftwareSpace(hw, layer)
    print(f"layer {layer.name}: {layer.macs/1e6:.1f}M MACs on Eyeriss "
          f"({hw.pe_mesh_x}x{hw.pe_mesh_y} PEs)")

    r_random = random_search(space, n_trials=100, seed=0)
    r_bo = bo_maximize(space, n_trials=100, n_warmup=25, pool_size=100, seed=0)

    for name, r in (("random", r_random), ("constrained BO", r_bo)):
        ev = evaluate(hw, r.best_point, layer)
        print(f"{name:16s}: EDP {ev.edp:.3e} pJ*cycles "
              f"(energy {ev.energy_pj:.3e} pJ, delay {ev.delay_cycles:.3e} cyc)")
    gain = 10 ** (r_bo.best_value - r_random.best_value)
    print(f"BO finds a {gain:.2f}x better EDP within the same 100-trial budget")

    m = r_bo.best_point
    print("\nbest mapping (factors per level, dims R,S,P,Q,C,K):")
    for lvl, row in zip(("LB", "spatialX", "spatialY", "GB", "DRAM"), m.factors):
        print(f"  {lvl:9s} {row}")
    print(f"  loop order GB:   {m.order_gb}")
    print(f"  loop order DRAM: {m.order_dram}")


if __name__ == "__main__":
    main()
