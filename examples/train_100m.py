"""End-to-end training driver: a ~100M-parameter llama-style model trained for
a few hundred steps on the synthetic Markov-chain pipeline, with checkpointing,
an injected mid-run fault (restart exercised for real), and loss reporting.

    PYTHONPATH=src python examples/train_100m.py --steps 300
"""

import argparse
import shutil
import tempfile

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.pipeline import DataConfig, SyntheticSource
from repro.launch import steps as S
from repro.optim import adamw
from repro.runtime.fault_tolerance import ResilientLoop

# ~100M params: 12 layers x d_model 768, llama-style GQA + SwiGLU.
CFG_100M = ModelConfig(
    name="repro-100m",
    family="dense",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=4,
    d_ff=2048,
    vocab_size=32768,
    remat="none",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--inject-fault", type=int, default=150,
                    help="step at which to inject a fault (-1 to disable)")
    args = ap.parse_args()

    cfg = CFG_100M
    shape = ShapeConfig("train100m", args.seq, args.batch, "train")
    opt_cfg = adamw.AdamWConfig(lr=6e-4, warmup_steps=30,
                                total_steps=args.steps)
    model, train_step = S.make_train_step(cfg, opt_cfg)
    jstep = jax.jit(train_step, donate_argnums=(0,))
    state = S.init_train_state(model, cfg, opt_cfg, jax.random.key(0))
    n = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"model: {n/1e6:.1f}M params | batch {args.batch}x{args.seq} "
          f"| {args.steps} steps")

    source = SyntheticSource(cfg, shape, DataConfig(seed=0))
    ckpt_dir = tempfile.mkdtemp(prefix="repro100m_")

    losses = []

    def step_fn(state, batch):
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        state, metrics = jstep(state, jb)
        return state, {k: float(v) for k, v in metrics.items()}

    def log(m):
        if "loss" in m:
            losses.append(m["loss"])
            if m["step"] % 25 == 0:
                print(f"step {m['step']:4d}  loss {m['loss']:.4f}  "
                      f"gnorm {m['grad_norm']:.2f}  {m['dt']*1e3:.0f} ms")
        else:
            print(f"*** {m}")

    loop = ResilientLoop(step_fn, source, ckpt_dir, save_every=50)
    faults = {args.inject_fault} if args.inject_fault >= 0 else None
    state, step, _, monitor = loop.run(state, 0, args.steps,
                                       fault_schedule=faults, log=log)
    first = sum(losses[:10]) / 10
    last = sum(losses[-10:]) / 10
    print(f"\ndone: loss {first:.3f} -> {last:.3f} "
          f"({'LEARNING' if last < first - 0.5 else 'check hyperparams'}) | "
          f"restarts survived, stragglers flagged: {monitor.flagged}")
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    assert last < first, "training must reduce loss"


if __name__ == "__main__":
    main()
