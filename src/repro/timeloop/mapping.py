"""Software-mapping parameterization (paper appendix Fig. 8 / Fig. 9).

A mapping factorizes every loop dim across four levels and fixes per-level loop
orders:

  S1-S6  blocking factors: dim = t_dram * t_gb * s_x * s_y * t_lb
         (s_x / s_y are the spatial `parallel_for` factors across the PE array)
  S7-S9  loop order (outermost-first permutation of DIMS) at LB, GB, DRAM

Validity (Fig. 9): per-dim factor products must equal the layer dims (guaranteed
constructively by the sampler), per-tensor LB tiles must fit the local sub-buffers,
the GB tile must fit the global buffer, and spatial factors must fit the PE mesh.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.timeloop.arch import HardwareConfig
from repro.timeloop.workloads import DIMS, ConvLayer, sampler_divisors

LEVELS = ("lb", "sx", "sy", "gb", "dram")


@dataclasses.dataclass(frozen=True)
class Mapping:
    # factors[level][dim] -> int; levels as in LEVELS.
    factors: tuple[tuple[int, ...], ...]  # shape (5, 6), indexed [level][dim]
    order_lb: tuple[str, ...]             # S7: permutation of DIMS, outermost first
    order_gb: tuple[str, ...]             # S8
    order_dram: tuple[str, ...]           # S9

    def f(self, level: str, dim: str) -> int:
        return self.factors[LEVELS.index(level)][DIMS.index(dim)]

    def cum(self, dim: str, upto: str) -> int:
        """Product of factors at `upto` level and all levels below it."""
        out = 1
        for lvl in LEVELS[: LEVELS.index(upto) + 1]:
            out *= self.f(lvl, dim)
        return out

    @property
    def spatial_x(self) -> int:
        return _prod(self.factors[LEVELS.index("sx")])

    @property
    def spatial_y(self) -> int:
        return _prod(self.factors[LEVELS.index("sy")])

    @property
    def used_pes(self) -> int:
        return self.spatial_x * self.spatial_y


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


# --- tile sizes ----------------------------------------------------------------

def lb_tiles(m: Mapping, layer: ConvLayer) -> dict[str, int]:
    """Per-tensor tile sizes resident in one PE's local buffer."""
    r, s = m.f("lb", "R"), m.f("lb", "S")
    p, q = m.f("lb", "P"), m.f("lb", "Q")
    c, k = m.f("lb", "C"), m.f("lb", "K")
    return {
        "W": r * s * c * k,
        "I": layer.input_extent(p, r) * layer.input_extent(q, s) * c,
        "O": p * q * k,
    }


def gb_tiles(m: Mapping, layer: ConvLayer) -> dict[str, int]:
    """Per-tensor tile sizes resident in the global buffer (covers the PE array)."""
    r, s = m.cum("R", "gb"), m.cum("S", "gb")
    p, q = m.cum("P", "gb"), m.cum("Q", "gb")
    c, k = m.cum("C", "gb"), m.cum("K", "gb")
    return {
        "W": r * s * c * k,
        "I": layer.input_extent(p, r) * layer.input_extent(q, s) * c,
        "O": p * q * k,
    }


# --- validity -------------------------------------------------------------------

def mapping_is_valid(m: Mapping, hw: HardwareConfig, layer: ConvLayer) -> tuple[bool, str]:
    for di, d in enumerate(DIMS):
        prod = _prod(tuple(m.factors[li][di] for li in range(len(LEVELS))))
        if prod != layer.dim(d):
            return False, f"factorization:{d}"
    # Dataflow options pin filter dims entirely inside the PE (H11/H12).
    if hw.df_fw == 2 and m.f("lb", "S") != layer.S:
        return False, "dataflow_fw"
    if hw.df_fh == 2 and m.f("lb", "R") != layer.R:
        return False, "dataflow_fh"
    lb = lb_tiles(m, layer)
    if lb["I"] > hw.lb_input:
        return False, "lb_input"
    if lb["W"] > hw.lb_weight:
        return False, "lb_weight"
    if lb["O"] > hw.lb_output:
        return False, "lb_output"
    gb = gb_tiles(m, layer)
    if gb["I"] + gb["W"] + gb["O"] > hw.gb_entries:
        return False, "gb_capacity"
    if m.spatial_x > hw.pe_mesh_x:
        return False, "spatial_x"
    if m.spatial_y > hw.pe_mesh_y:
        return False, "spatial_y"
    return True, "ok"


# --- sampling --------------------------------------------------------------------

def _random_split(rng, n: int, parts: int) -> list[int]:
    """Random factorization of n into `parts` ordered factors (uniform over chains)."""
    out = []
    rem = n
    for i in range(parts - 1):
        d = sampler_divisors(rem)
        f = int(d[rng.integers(len(d))])
        out.append(f)
        rem //= f
    out.append(rem)
    return out


def random_mapping(rng, hw: HardwareConfig, layer: ConvLayer) -> Mapping:
    """Draw a structurally consistent mapping (factor products match the layer);
    capacity/spatial validity is NOT guaranteed -- callers rejection-sample."""
    per_level = {lvl: [1] * len(DIMS) for lvl in LEVELS}
    for di, d in enumerate(DIMS):
        n = layer.dim(d)
        if d == "S" and hw.df_fw == 2:
            lb, rest = n, 1
        elif d == "R" and hw.df_fh == 2:
            lb, rest = n, 1
        else:
            lb = int(sampler_divisors(n)[rng.integers(len(sampler_divisors(n)))])
            rest = n // lb
        sx, rest = _pick(rng, rest)
        sy, rest = _pick(rng, rest)
        gb, dram = _pick(rng, rest)
        per_level["lb"][di] = lb
        per_level["sx"][di] = sx
        per_level["sy"][di] = sy
        per_level["gb"][di] = gb
        per_level["dram"][di] = dram
    factors = tuple(tuple(per_level[lvl]) for lvl in LEVELS)
    return Mapping(
        factors=factors,
        order_lb=tuple(rng.permutation(DIMS)),
        order_gb=tuple(rng.permutation(DIMS)),
        order_dram=tuple(rng.permutation(DIMS)),
    )


def sample_constrained_batch(
    rng, hw: HardwareConfig, layer: ConvLayer, n: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized twin of `constrained_random_mapping`: draw a whole candidate
    pool in one shot.

    Returns packed arrays `(factors, order_lb, order_gb, order_dram)` with
    `factors` of shape (n, 5, 6) — levels in LEVELS order, dims in DIMS order —
    and each order an (n, 6) dim-index permutation, outermost first (the
    encoding consumed by `repro.timeloop.batch.MappingBatch`).

    Semantics match the scalar sampler: dataflow pins are honored, LB-capacity
    and PE-mesh constraints are enforced *during* the draw (per-dim uniform
    choice over the feasible divisors of the remaining extent), and the GB/DRAM
    split is a uniform divisor pick — so only GB capacity can still reject.
    The one divergence is that the dim processing order is one random
    permutation shared across the batch rather than per-row (per-row orders
    would serialize the draw again); pool statistics are indistinguishable.
    """
    B = int(n)
    n_dims = len(DIMS)
    # LEVELS order: lb, sx, sy, gb, dram
    i_lb, i_sx, i_sy, i_gb, i_dram = range(len(LEVELS))
    factors = np.ones((B, len(LEVELS), n_dims), dtype=np.int64)
    rem = np.tile(
        np.array([layer.dim(d) for d in DIMS], dtype=np.int64), (B, 1)
    )
    divs = [np.array(sampler_divisors(layer.dim(d)), dtype=np.int64)
            for d in DIMS]

    pinned = [False] * n_dims
    if hw.df_fw == 2:
        si = DIMS.index("S")
        factors[:, i_lb, si] = layer.S
        rem[:, si] //= layer.S
        pinned[si] = True
    if hw.df_fh == 2:
        ri = DIMS.index("R")
        factors[:, i_lb, ri] = layer.R
        rem[:, ri] //= layer.R
        pinned[ri] = True

    def choose(D: np.ndarray, mask: np.ndarray) -> np.ndarray:
        """Per-row uniform choice among masked candidates; 1 where none."""
        counts = mask.sum(axis=1)
        idx = np.minimum(
            (rng.random(B) * counts).astype(np.int64),
            np.maximum(counts - 1, 0),
        )
        cum = np.cumsum(mask, axis=1)
        sel = (cum > idx[:, None]).argmax(axis=1)
        return np.where(counts > 0, D[sel], 1)

    # --- LB factors: capacity-feasible divisor choice per dim.
    for di in rng.permutation(n_dims):
        if pinned[di]:
            continue
        D = divs[di]
        cand = (rem[:, di : di + 1] % D[None, :]) == 0
        cols = [factors[:, i_lb, j : j + 1] for j in range(n_dims)]
        cols[di] = np.broadcast_to(D[None, :], (B, len(D)))
        r, s, p, q, c, k = cols
        # layer.input_extent is pure arithmetic -> broadcasts over the
        # (rows, candidates) grid; same formula as the scalar validity check.
        ok = (
            (r * s * c * k <= hw.lb_weight)
            & (layer.input_extent(p, r) * layer.input_extent(q, s) * c
               <= hw.lb_input)
            & (p * q * k <= hw.lb_output)
        )
        f = choose(D, cand & ok)
        factors[:, i_lb, di] = f
        rem[:, di] //= f

    # --- Spatial factors: running-product bound by the PE mesh.
    for lvl, cap in ((i_sx, hw.pe_mesh_x), (i_sy, hw.pe_mesh_y)):
        for di in rng.permutation(n_dims):
            D = divs[di]
            budget = cap // factors[:, lvl, :].prod(axis=1)
            mask = ((rem[:, di : di + 1] % D[None, :]) == 0) & (
                D[None, :] <= budget[:, None]
            )
            f = choose(D, mask)
            factors[:, lvl, di] = f
            rem[:, di] //= f

    # --- GB / DRAM split of the remainder.
    for di in range(n_dims):
        D = divs[di]
        gb = choose(D, (rem[:, di : di + 1] % D[None, :]) == 0)
        factors[:, i_gb, di] = gb
        factors[:, i_dram, di] = rem[:, di] // gb

    def rand_orders() -> np.ndarray:
        return np.argsort(rng.random((B, n_dims)), axis=1).astype(np.int64)

    return factors, rand_orders(), rand_orders(), rand_orders()


def _pick(rng, n: int) -> tuple[int, int]:
    d = sampler_divisors(n)
    f = int(d[rng.integers(len(d))])
    return f, n // f


def constrained_random_mapping(rng, hw: HardwareConfig, layer: ConvLayer) -> Mapping:
    """Constraint-aware sampler implementing the paper's *input constraints*: the
    LB-capacity and spatial-mesh constraints are enforced during sampling (the
    paper's "valid ranges" depend on the hardware), so only the GB-capacity
    constraint can still reject.  This is the sampler used to build the
    150-candidate feasible pools for acquisition optimization."""
    per_level = {lvl: [1] * len(DIMS) for lvl in LEVELS}
    rem = {d: layer.dim(d) for d in DIMS}

    # --- LB factors: respect dataflow pins, then greedily bound by capacity.
    if hw.df_fw == 2:
        per_level["lb"][DIMS.index("S")] = layer.S
        rem["S"] //= layer.S
    if hw.df_fh == 2:
        per_level["lb"][DIMS.index("R")] = layer.R
        rem["R"] //= layer.R

    def tiles_ok(fl: list[int]) -> bool:
        r, s, p, q, c, k = fl
        if r * s * c * k > hw.lb_weight:
            return False
        if layer.input_extent(p, r) * layer.input_extent(q, s) * c > hw.lb_input:
            return False
        return p * q * k <= hw.lb_output

    dim_order = list(rng.permutation(len(DIMS)))
    for di in dim_order:
        d = DIMS[di]
        if (d == "S" and hw.df_fw == 2) or (d == "R" and hw.df_fh == 2):
            continue
        cands = []
        for f in sampler_divisors(rem[d]):
            trial = list(per_level["lb"])
            trial[di] = f
            if tiles_ok(trial):
                cands.append(f)
        f = int(cands[rng.integers(len(cands))]) if cands else 1
        per_level["lb"][di] = f
        rem[d] //= f

    # --- Spatial factors: running-product bound by the PE mesh.
    for axis, cap in (("sx", hw.pe_mesh_x), ("sy", hw.pe_mesh_y)):
        for di in rng.permutation(len(DIMS)):
            d = DIMS[di]
            budget = cap // _prod(per_level[axis])
            cands = [f for f in sampler_divisors(rem[d]) if f <= budget]
            f = int(cands[rng.integers(len(cands))])
            per_level[axis][di] = f
            rem[d] //= f

    # --- GB / DRAM split of the remainder.
    for di, d in enumerate(DIMS):
        gb, dram = _pick(rng, rem[d])
        per_level["gb"][di] = gb
        per_level["dram"][di] = dram

    factors = tuple(tuple(per_level[lvl]) for lvl in LEVELS)
    return Mapping(
        factors=factors,
        order_lb=tuple(rng.permutation(DIMS)),
        order_gb=tuple(rng.permutation(DIMS)),
        order_dram=tuple(rng.permutation(DIMS)),
    )
