"""Hardware parameterization (paper appendix Fig. 6 / Fig. 7).

A design point fixes:
  H1/H2   PE mesh-X / mesh-Y            (H1 * H2 == num_pes)
  H3-H5   local-buffer partition        (input/weight/output entries, sum <= budget)
  H6-H8   global-buffer instances/mesh  (H7 * H8 == H6, H7 | H1, H8 | H2)
  H9/H10  global-buffer block / cluster (factors of 16)
  H11/H12 dataflow options              (1 = free, 2 = filter dim pinned in PE)

The compute (num_pes) and total storage budgets are fixed to the Eyeriss baseline,
matching the paper's "same compute and storage resource constraints" setup.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.timeloop.workloads import divisors


@dataclasses.dataclass(frozen=True)
class EnergyTable:
    """Energy per access (pJ), Eyeriss-relative (Chen et al. 2016, Table II)."""

    mac: float = 1.0
    lb: float = 1.0       # per-PE register-file/scratchpad access
    noc: float = 2.0      # global buffer -> PE network hop
    gb: float = 6.0       # global buffer access
    dram: float = 200.0   # off-chip DRAM access


@dataclasses.dataclass(frozen=True)
class HardwareConfig:
    # Fixed resource budgets (Eyeriss-equivalent).
    num_pes: int = 168
    lb_budget: int = 512          # local-buffer entries per PE (H3+H4+H5 <= this)
    gb_entries: int = 55296       # global-buffer capacity in words (108KB / 2B)
    dram_bandwidth: float = 16.0  # words / cycle

    # H1-H12 searchable parameters.
    pe_mesh_x: int = 12           # H1
    pe_mesh_y: int = 14           # H2
    lb_input: int = 192           # H3
    lb_weight: int = 224          # H4
    lb_output: int = 96           # H5
    gb_instances: int = 1         # H6
    gb_mesh_x: int = 1            # H7
    gb_mesh_y: int = 1            # H8
    gb_block: int = 4             # H9 (words per GB entry row -> read width)
    gb_cluster: int = 1           # H10 (entries ganged into wider structures)
    df_fw: int = 1                # H11 (2 => filter width pinned in PE: S_lb == S)
    df_fh: int = 1                # H12 (2 => filter height pinned in PE: R_lb == R)

    energy: EnergyTable = dataclasses.field(default_factory=EnergyTable)

    @property
    def gb_bandwidth(self) -> float:
        """Words/cycle deliverable by the global buffer to the PE array."""
        return float(self.gb_block * self.gb_cluster * self.gb_instances)

    @property
    def gb_access_energy(self) -> float:
        """Per-word GB energy; wider/ganged reads amortize the access cost."""
        width = self.gb_block * self.gb_cluster
        # Access energy grows ~sqrt(width) for the wider row, amortized over width.
        return self.energy.gb * (width ** 0.5) / width


def hw_from_tuple(t) -> HardwareConfig:
    """Rebuild a `HardwareConfig` from its `dataclasses.astuple` image (the
    wire form persisted by `repro.service.store`).  The last field is the
    nested `EnergyTable`, which `astuple` recurses into -- a naive
    `HardwareConfig(*t)` would hand the energy slot a plain tuple."""
    return HardwareConfig(*t[:-1], energy=EnergyTable(*t[-1]))


def hw_is_valid(hw: HardwareConfig) -> tuple[bool, str]:
    """Known (input) hardware constraints from appendix Fig. 7."""
    if hw.pe_mesh_x * hw.pe_mesh_y != hw.num_pes:
        return False, "pe_mesh"
    if hw.lb_input + hw.lb_weight + hw.lb_output > hw.lb_budget:
        return False, "lb_budget"
    if min(hw.lb_input, hw.lb_weight, hw.lb_output) < 1:
        return False, "lb_partition"
    if hw.gb_mesh_x * hw.gb_mesh_y != hw.gb_instances:
        return False, "gb_mesh"
    if hw.pe_mesh_x % hw.gb_mesh_x or hw.pe_mesh_y % hw.gb_mesh_y:
        return False, "gb_mesh_divides_pe_mesh"
    if 16 % hw.gb_block or 16 % hw.gb_cluster:
        return False, "gb_block_cluster"
    if hw.df_fw not in (1, 2) or hw.df_fh not in (1, 2):
        return False, "dataflow_option"
    return True, "ok"


def sample_hardware_pool(
    rng, n: int, num_pes: int = 168, base: HardwareConfig | None = None
) -> list[HardwareConfig]:
    """Draw n structurally-valid hardware points with array-vectorized
    parameter sampling (the batched-protocol pool path of `HardwareSpace`):
    every random draw is a whole-(n,) array op, so building the outer BO
    loop's 150-candidate pools stops paying per-candidate RNG/python cost.

    Every draw satisfies `hw_is_valid` by construction (mesh products and the
    LB composition are exact, block/cluster come from divisors of 16), like
    the scalar `sample_hardware` -- no rejection round is needed."""
    base = base or HardwareConfig(num_pes=num_pes)
    if base.lb_budget < 3:
        # Cannot compose the budget into 3 positive parts; fail loudly like
        # the scalar sampler (whose no-replacement choice raises) instead of
        # spinning in the distinct-cut redraw below.
        raise ValueError(
            f"lb_budget must be >= 3 to split into I/W/O, got {base.lb_budget}")
    mesh_divs = np.asarray(divisors(num_pes), dtype=np.int64)
    mx = rng.choice(mesh_divs, size=n)
    my = num_pes // mx
    # LB partition: random composition of the budget into 3 positive parts
    # (two distinct cut points; equal pairs are redrawn, which matches
    # choice-without-replacement in distribution).
    a = rng.integers(1, base.lb_budget, size=n)
    b = rng.integers(1, base.lb_budget, size=n)
    clash = a == b
    while clash.any():
        b[clash] = rng.integers(1, base.lb_budget, size=int(clash.sum()))
        clash = a == b
    lo, hi = np.minimum(a, b), np.maximum(a, b)
    # GB mesh divisor picks are ragged per row (divisors of mx/my), so draw a
    # uniform variate per row and index each row's divisor list with it.
    u_gx, u_gy = rng.random(n), rng.random(n)
    gx = np.empty(n, dtype=np.int64)
    gy = np.empty(n, dtype=np.int64)
    for i in range(n):
        dx = divisors(int(mx[i]))
        dy = divisors(int(my[i]))
        gx[i] = dx[int(u_gx[i] * len(dx))]
        gy[i] = dy[int(u_gy[i] * len(dy))]
    blocks = np.asarray([1, 2, 4, 8, 16], dtype=np.int64)
    gb_block = rng.choice(blocks, size=n)
    gb_cluster = rng.choice(blocks, size=n)
    df_fw = rng.choice(np.asarray([1, 2]), size=n)
    df_fh = rng.choice(np.asarray([1, 2]), size=n)
    return [
        dataclasses.replace(
            base,
            num_pes=num_pes,
            pe_mesh_x=int(mx[i]),
            pe_mesh_y=int(my[i]),
            lb_input=int(lo[i]),
            lb_weight=int(hi[i] - lo[i]),
            lb_output=int(base.lb_budget - hi[i]),
            gb_instances=int(gx[i] * gy[i]),
            gb_mesh_x=int(gx[i]),
            gb_mesh_y=int(gy[i]),
            gb_block=int(gb_block[i]),
            gb_cluster=int(gb_cluster[i]),
            df_fw=int(df_fw[i]),
            df_fh=int(df_fh[i]),
        )
        for i in range(n)
    ]


def sample_hardware(rng, num_pes: int = 168, base: HardwareConfig | None = None) -> HardwareConfig:
    """Draw a uniform random hardware point satisfying the *structural* constraints
    (mesh products); the capacity constraint is checked by hw_is_valid afterwards."""
    base = base or HardwareConfig(num_pes=num_pes)
    mesh_divs = divisors(num_pes)
    mx = int(rng.choice(mesh_divs))
    my = num_pes // mx
    # LB partition: random composition of the budget into 3 positive parts.
    cut = sorted(rng.choice(range(1, base.lb_budget), size=2, replace=False))
    li, lw, lo = cut[0], cut[1] - cut[0], base.lb_budget - cut[1]
    gx = int(rng.choice(divisors(mx)))
    gy = int(rng.choice(divisors(my)))
    return dataclasses.replace(
        base,
        num_pes=num_pes,
        pe_mesh_x=mx,
        pe_mesh_y=my,
        lb_input=int(li),
        lb_weight=int(lw),
        lb_output=int(lo),
        gb_instances=gx * gy,
        gb_mesh_x=gx,
        gb_mesh_y=gy,
        gb_block=int(rng.choice([1, 2, 4, 8, 16])),
        gb_cluster=int(rng.choice([1, 2, 4, 8, 16])),
        df_fw=int(rng.choice([1, 2])),
        df_fh=int(rng.choice([1, 2])),
    )
