"""Vectorized batch evaluation engine for the co-design hot path.

The nested search (paper §4.1) evaluates `n_hw x n_layers x 250` inner BO
trials, and every trial samples and scores a ~150-candidate mapping pool.  The
scalar path in `model.py` / `mapping.py` walks Python dicts and string-keyed
lookups one mapping at a time, which makes the *analytical model* — not the GP —
the wall-clock bottleneck.  This module packs whole candidate pools into NumPy
arrays and evaluates them in one shot:

  MappingBatch.factors      int64 (B, 5, 6)   blocking factors, indexed
                                              [batch, level, dim] with levels in
                                              `mapping.LEVELS` order
                                              (lb, sx, sy, gb, dram) and dims in
                                              `workloads.DIMS` order (R S P Q C K)
  MappingBatch.order_*      int64 (B, 6)      loop orders as dim-index
                                              permutations, outermost first

On top of that encoding it provides vectorized twins of the scalar reference:

  lb_tiles_batch / gb_tiles_batch   <->  mapping.lb_tiles / gb_tiles
  valid_batch                       <->  mapping.mapping_is_valid
  level_trips_batch / passes_batch  <->  model._level_trips / model._passes
  evaluate_batch                    <->  model.evaluate  (EDP / energy / delay)
  features_batch                    <->  swspace.SoftwareSpace.features

All are bit-for-bit parity-tested against the scalar reference in
`tests/test_batch.py` (to 1e-9 relative error; the only divergence source is
float64 rounding where the scalar path used exact Python ints).

Everything is plain NumPy so it runs fast on CPU with no compile latency; the
encoding is deliberately JAX-friendly (fixed-shape int arrays, no ragged
structures), so a `jax.vmap`/`pallas` backend can reuse it unchanged — see
ROADMAP "Open items".
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.timeloop.arch import HardwareConfig
from repro.timeloop.mapping import LEVELS, Mapping, sample_constrained_batch
from repro.timeloop.workloads import DIMS, RELEVANCE, ConvLayer

# Level indices into MappingBatch.factors (LEVELS order: lb, sx, sy, gb, dram).
L_LB, L_SX, L_SY, L_GB, L_DRAM = range(len(LEVELS))
# Dim indices (DIMS order: R, S, P, Q, C, K).
D_R, D_S, D_P, D_Q, D_C, D_K = range(len(DIMS))

# Boolean relevance masks in DIMS order, per tensor.
REL_MASKS = {
    t: np.array([d in RELEVANCE[t] for d in DIMS], dtype=bool)
    for t in ("W", "I", "O")
}
TENSORS = ("W", "I", "O")


@dataclasses.dataclass(frozen=True)
class MappingBatch:
    """A pool of B mappings in packed array form (see module docstring)."""

    factors: np.ndarray     # (B, 5, 6) int64
    order_lb: np.ndarray    # (B, 6) int64 dim indices, outermost first
    order_gb: np.ndarray    # (B, 6)
    order_dram: np.ndarray  # (B, 6)

    def __len__(self) -> int:
        return self.factors.shape[0]

    def __getitem__(self, i: int) -> Mapping:
        """Unpack row i into a scalar `Mapping`."""
        return Mapping(
            factors=tuple(tuple(int(x) for x in row) for row in self.factors[i]),
            order_lb=tuple(DIMS[j] for j in self.order_lb[i]),
            order_gb=tuple(DIMS[j] for j in self.order_gb[i]),
            order_dram=tuple(DIMS[j] for j in self.order_dram[i]),
        )

    def take(self, idx) -> "MappingBatch":
        """Row-subset (fancy-index) view of the pool."""
        return MappingBatch(
            factors=self.factors[idx],
            order_lb=self.order_lb[idx],
            order_gb=self.order_gb[idx],
            order_dram=self.order_dram[idx],
        )


def pack(mappings: list[Mapping] | tuple[Mapping, ...]) -> MappingBatch:
    """Pack scalar `Mapping`s into a `MappingBatch`."""
    dim_idx = {d: j for j, d in enumerate(DIMS)}
    factors = np.array([m.factors for m in mappings], dtype=np.int64)
    if factors.size == 0:
        factors = factors.reshape(0, len(LEVELS), len(DIMS))

    def orders(attr):
        return np.array(
            [[dim_idx[d] for d in getattr(m, attr)] for m in mappings],
            dtype=np.int64,
        ).reshape(len(mappings), len(DIMS))

    return MappingBatch(factors, orders("order_lb"), orders("order_gb"),
                        orders("order_dram"))


def concat(batches: list[MappingBatch]) -> MappingBatch:
    return MappingBatch(
        factors=np.concatenate([b.factors for b in batches], axis=0),
        order_lb=np.concatenate([b.order_lb for b in batches], axis=0),
        order_gb=np.concatenate([b.order_gb for b in batches], axis=0),
        order_dram=np.concatenate([b.order_dram for b in batches], axis=0),
    )


# --- tile sizes ----------------------------------------------------------------

def _tiles(f: np.ndarray, layer: ConvLayer) -> np.ndarray:
    """Per-tensor tile sizes (B, 3) [W, I, O] from per-dim factors f (B, 6).

    `ConvLayer.input_extent` is pure arithmetic, so it broadcasts over arrays —
    the halo formula stays defined in exactly one place.
    """
    r, s, p, q, c, k = (f[:, j] for j in range(6))
    return np.stack(
        [
            r * s * c * k,
            layer.input_extent(p, r) * layer.input_extent(q, s) * c,
            p * q * k,
        ],
        axis=1,
    )


def lb_tiles_batch(mb: MappingBatch, layer: ConvLayer) -> np.ndarray:
    """(B, 3) [W, I, O] tile sizes resident in one PE's local buffer."""
    return _tiles(mb.factors[:, L_LB, :], layer)


def gb_tiles_batch(mb: MappingBatch, layer: ConvLayer) -> np.ndarray:
    """(B, 3) [W, I, O] tile sizes resident in the global buffer."""
    cum = mb.factors[:, : L_GB + 1, :].prod(axis=1)
    return _tiles(cum, layer)


# --- validity ------------------------------------------------------------------

def _valid_from_tiles(
    mb: MappingBatch,
    hw: HardwareConfig,
    layer: ConvLayer,
    lb: np.ndarray,
    gb: np.ndarray,
) -> np.ndarray:
    """Validity given precomputed lb/gb tile arrays (lets evaluate_batch reuse
    the tiles it needs anyway instead of recomputing them)."""
    dims = np.array([layer.dim(d) for d in DIMS], dtype=np.int64)
    ok = (mb.factors.prod(axis=1) == dims[None, :]).all(axis=1)
    if hw.df_fw == 2:
        ok &= mb.factors[:, L_LB, D_S] == layer.S
    if hw.df_fh == 2:
        ok &= mb.factors[:, L_LB, D_R] == layer.R
    ok &= lb[:, 0] <= hw.lb_weight
    ok &= lb[:, 1] <= hw.lb_input
    ok &= lb[:, 2] <= hw.lb_output
    ok &= gb.sum(axis=1) <= hw.gb_entries
    ok &= mb.factors[:, L_SX, :].prod(axis=1) <= hw.pe_mesh_x
    ok &= mb.factors[:, L_SY, :].prod(axis=1) <= hw.pe_mesh_y
    return ok


def valid_batch(mb: MappingBatch, hw: HardwareConfig, layer: ConvLayer) -> np.ndarray:
    """(B,) bool — vectorized twin of `mapping_is_valid`."""
    return _valid_from_tiles(
        mb, hw, layer, lb_tiles_batch(mb, layer), gb_tiles_batch(mb, layer)
    )


# --- trip counts ---------------------------------------------------------------

_POS = np.arange(len(DIMS))


def level_trips_batch(order: np.ndarray, f: np.ndarray, rel: np.ndarray) -> np.ndarray:
    """Vectorized `_level_trips`: (B,) refetch-forcing iterations per level.

    order: (B, 6) dim-index permutation, outermost first.
    f:     (B, 6) per-dim factors at this level (DIMS order).
    rel:   (6,) bool relevance mask (DIMS order).

    Filtering to active (factor > 1) loops preserves order, so the scalar
    "position within the active list" comparisons are equivalent to raw
    position comparisons here; inactive loops contribute factor 1 anyway.
    """
    fo = np.take_along_axis(f, order, axis=1)        # factors in loop order
    rel_o = rel[order]                               # relevance in loop order
    rel_active = rel_o & (fo > 1)
    has_rel = rel_active.any(axis=1)
    innermost = np.where(rel_active, _POS[None, :], -1).max(axis=1)
    include = rel_o | (_POS[None, :] < innermost[:, None])
    trips = np.where(include, fo, 1).prod(axis=1)
    return np.where(has_rel, trips, 1)


def passes_batch(order: np.ndarray, f: np.ndarray) -> np.ndarray:
    """Vectorized `_passes` for outputs: (B,) reduction passes at this level."""
    rel = REL_MASKS["O"]
    fo = np.take_along_axis(f, order, axis=1)
    rel_o = rel[order]
    rel_active = rel_o & (fo > 1)
    anchor = np.where(rel_active, _POS[None, :], len(DIMS)).min(axis=1)
    include = (~rel_o) & (_POS[None, :] < anchor[:, None])
    return np.where(include, fo, 1).prod(axis=1)


# --- EDP evaluation ------------------------------------------------------------

def evaluate_batch(
    hw: HardwareConfig, mb: MappingBatch, layer: ConvLayer
) -> dict[str, np.ndarray]:
    """Vectorized `model.evaluate` over the whole pool.

    Returns float64 arrays keyed `energy_pj`, `delay_cycles`, `edp` (inf on
    invalid rows) and a bool array `valid`.
    """
    lb_int = lb_tiles_batch(mb, layer)
    gb_int = gb_tiles_batch(mb, layer)
    valid = _valid_from_tiles(mb, hw, layer, lb_int, gb_int)
    e = hw.energy
    macs = float(layer.macs)
    used_pes = (
        mb.factors[:, L_SX, :].prod(axis=1) * mb.factors[:, L_SY, :].prod(axis=1)
    ).astype(np.float64)

    lb = lb_int.astype(np.float64)
    gb = gb_int.astype(np.float64)

    f_gb = mb.factors[:, L_GB, :]
    f_dram = mb.factors[:, L_DRAM, :]
    sp = mb.factors[:, L_SX, :] * mb.factors[:, L_SY, :]
    sp_all = sp.prod(axis=1).astype(np.float64)

    lb_acc = np.zeros(len(mb))
    noc_acc = np.zeros(len(mb))
    gb_acc = np.zeros(len(mb))
    dram_acc = np.zeros(len(mb))

    for ti, t in enumerate(TENSORS):
        rel = REL_MASKS[t]
        gb_trips = level_trips_batch(mb.order_gb, f_gb, rel).astype(np.float64)
        dram_trips = level_trips_batch(mb.order_dram, f_dram, rel).astype(np.float64)
        sp_rel = np.where(rel[None, :], sp, 1).prod(axis=1).astype(np.float64)

        fills_lb = lb[:, ti] * gb_trips * dram_trips
        if t == "O":
            rw = 2.0 * passes_batch(mb.order_gb, f_gb) - 1.0
        else:
            rw = 1.0
        gb_acc += fills_lb * sp_rel * rw
        noc_acc += fills_lb * sp_all * rw
        lb_acc += fills_lb * sp_all * rw

        fills_gb = gb[:, ti] * dram_trips
        if t == "O":
            rw_d = 2.0 * passes_batch(mb.order_dram, f_dram) - 1.0
        else:
            rw_d = 1.0
        dram_acc += fills_gb * rw_d

    lb_acc += 4.0 * macs

    energy = (
        macs * e.mac
        + lb_acc * e.lb
        + noc_acc * e.noc
        + gb_acc * hw.gb_access_energy
        + dram_acc * e.dram
    )
    with np.errstate(divide="ignore", invalid="ignore"):
        compute_cycles = macs / used_pes
    delay = np.maximum(
        compute_cycles,
        np.maximum(gb_acc / hw.gb_bandwidth, dram_acc / hw.dram_bandwidth),
    )
    edp = energy * delay

    inf = np.float64(np.inf)
    return {
        "energy_pj": np.where(valid, energy, inf),
        "delay_cycles": np.where(valid, delay, inf),
        "edp": np.where(valid, edp, inf),
        "valid": valid,
    }


# --- EDP lower bounds (bound-and-prune pass) -------------------------------------

def edp_lower_bounds_batch(hwb: np.ndarray, layb: np.ndarray,
                           caps: np.ndarray) -> np.ndarray:
    """(n_hw, L) provable EDP lower bounds over a pool x layer stack.

    `hwb` is the (n, 11) matrix of `bounds.hw_bound_vecs` -- the `edp_reduce`
    consts block [e_mac, e_lb, e_noc, e_gb_acc, e_dram, gb_bw, dram_bw] with
    mesh shape + dataflow pins appended -- `layb` the (L, 2)
    [macs, traffic_lb] matrix of `bounds.layer_bound_vecs`, and `caps` the
    (L, 4, A) sorted spatial-cap tables of `bounds.layer_caps` (one row per
    dataflow variant).  Whole-array twin of `bounds.lower_bound` (derivation
    there), parity-pinned in tests/test_bounds.py.
    """
    hwb = np.asarray(hwb, np.float64)
    layb = np.asarray(layb, np.float64)
    caps = np.asarray(caps, np.float64)
    e_mac, e_lb, e_noc, e_gb, e_dram, gb_bw, dram_bw = (
        hwb[:, j:j + 1] for j in range(7))
    mx, my = hwb[:, 7], hwb[:, 8]
    # dataflow variant per config: v = 2*(df_fh==2) + (df_fw==2)
    v = (2 * (hwb[:, 10] == 2.0) + (hwb[:, 9] == 2.0)).astype(np.intp)
    capsel = caps[:, v, :]  # (L, n, A): each config's variant row, per layer
    # largest achievable spatial product <= each mesh axis (tables contain 1)
    ax = np.max(np.where(capsel <= mx[None, :, None], capsel, 1.0), axis=-1)
    ay = np.max(np.where(capsel <= my[None, :, None], capsel, 1.0), axis=-1)
    used = (ax * ay).T  # (n, L) best-achievable PE count
    macs, traffic = layb[:, 0][None, :], layb[:, 1][None, :]
    energy = (macs * e_mac + (4.0 * macs + traffic) * e_lb
              + traffic * (e_noc + e_gb + e_dram))
    delay = np.maximum(macs / used,
                       np.maximum(traffic / gb_bw, traffic / dram_bw))
    return energy * delay


# --- features ------------------------------------------------------------------

def features_batch(
    mb: MappingBatch, hw: HardwareConfig, layer: ConvLayer
) -> np.ndarray:
    """(B, 14) feature matrix — vectorized `SoftwareSpace.features`."""
    lb = lb_tiles_batch(mb, layer).astype(np.float64)
    gb = gb_tiles_batch(mb, layer).astype(np.float64)
    f_gb = mb.factors[:, L_GB, :]
    f_dram = mb.factors[:, L_DRAM, :]
    trips = [
        np.log1p(level_trips_batch(order, f, REL_MASKS[t]).astype(np.float64))
        for f, order in ((f_gb, mb.order_gb), (f_dram, mb.order_dram))
        for t in TENSORS
    ]
    sx = mb.factors[:, L_SX, :].prod(axis=1).astype(np.float64)
    sy = mb.factors[:, L_SY, :].prod(axis=1).astype(np.float64)
    used = sx * sy
    cols = [
        lb[:, 1] / hw.lb_input,
        lb[:, 0] / hw.lb_weight,
        lb[:, 2] / hw.lb_output,
        gb.sum(axis=1) / hw.gb_entries,
        sx / hw.pe_mesh_x,
        sy / hw.pe_mesh_y,
        *trips,
        np.log1p(used),
        np.log1p(layer.macs / used),
    ]
    return np.stack(cols, axis=1)


# --- pool sampling -------------------------------------------------------------

def sample_valid_pool(
    rng,
    hw: HardwareConfig,
    layer: ConvLayer,
    n: int,
    max_rounds: int = 64,
) -> MappingBatch | None:
    """Draw n *valid* mappings in vectorized rounds of constrained sampling.

    The constrained sampler enforces LB-capacity and mesh constraints during
    the draw; only GB capacity can still reject, so a couple of oversampled
    rounds normally suffice.  Returns None when the space looks empirically
    empty (the BO layer converts that into `InfeasibleSpace`).
    """
    if n <= 0:
        return pack([])
    kept: list[MappingBatch] = []
    have = 0
    drawn = 0
    for _ in range(max_rounds):
        if drawn == 0:
            draw = n
        else:
            # Oversample by the observed valid rate so one more round usually
            # finishes the pool; the floor keeps pathological rates bounded.
            rate = max(have / drawn, 0.02)
            draw = min(int((n - have) / rate * 1.25) + 1, 64 * n)
        mb = MappingBatch(*sample_constrained_batch(rng, hw, layer, draw))
        drawn += draw
        ok = valid_batch(mb, hw, layer)
        if ok.any():
            kept.append(mb.take(np.flatnonzero(ok)))
            have += int(ok.sum())
        if have >= n:
            full = kept[0] if len(kept) == 1 else concat(kept)
            return full.take(np.arange(n))
    return None
