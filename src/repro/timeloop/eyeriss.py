"""Eyeriss baseline configurations and the heuristic baseline mapper.

The paper's baseline is the hand-designed Eyeriss accelerator (168 PEs; 256 for
the Transformer) with software mappings found by Timeloop's heuristic random
mapper.  We reproduce that: the canonical Eyeriss hardware point plus a
seeded constrained random search with a generous sample budget standing in for
the hand-tuned mapping.
"""

from __future__ import annotations

import numpy as np

from repro.timeloop.arch import HardwareConfig
from repro.timeloop.mapping import (Mapping, constrained_random_mapping,
                                    mapping_is_valid, random_mapping)
from repro.timeloop.model import Evaluation, evaluate
from repro.timeloop.workloads import ConvLayer


def eyeriss_168() -> HardwareConfig:
    """Eyeriss v1: 12x14 PE array, 108KB global buffer, RF split I/W/O."""
    return HardwareConfig(
        num_pes=168,
        pe_mesh_x=12,
        pe_mesh_y=14,
        lb_input=192,
        lb_weight=224,
        lb_output=96,
        gb_entries=55296,
        gb_instances=1,
        gb_mesh_x=1,
        gb_mesh_y=1,
        gb_block=4,
        gb_cluster=1,
        df_fw=1,
        df_fh=1,
    )


def eyeriss_256() -> HardwareConfig:
    """The larger Eyeriss configuration used for the Transformer (Parashar 2019)."""
    return HardwareConfig(
        num_pes=256,
        pe_mesh_x=16,
        pe_mesh_y=16,
        lb_input=192,
        lb_weight=224,
        lb_output=96,
        gb_entries=65536,
        gb_instances=1,
        gb_mesh_x=1,
        gb_mesh_y=1,
        gb_block=4,
        gb_cluster=1,
        df_fw=1,
        df_fh=1,
    )


def baseline_mapper(
    hw: HardwareConfig,
    layer: ConvLayer,
    budget: int = 2000,
    seed: int = 0,
) -> tuple[Mapping | None, Evaluation | None]:
    """Timeloop-style heuristic random mapper: constraint-pruned random search
    (Timeloop's mapper prunes capacity-invalid tilings before evaluation),
    keeping the best feasible mapping found within `budget` samples."""
    rng = np.random.default_rng(seed)
    best_m, best_e = None, None
    for _ in range(budget):
        m = constrained_random_mapping(rng, hw, layer)
        ok, _ = mapping_is_valid(m, hw, layer)
        if not ok:
            continue
        ev = evaluate(hw, m, layer)
        if best_e is None or ev.edp < best_e.edp:
            best_m, best_e = m, ev
    return best_m, best_e


def eyeriss_baseline_edp(
    layers: list[ConvLayer],
    num_pes: int = 168,
    budget: int = 2000,
    seed: int = 0,
) -> dict[str, float]:
    """Per-layer baseline EDP for a model's layers on the Eyeriss config."""
    hw = eyeriss_168() if num_pes == 168 else eyeriss_256()
    out = {}
    for layer in layers:
        _, ev = baseline_mapper(hw, layer, budget=budget, seed=seed)
        out[layer.name] = ev.edp if ev is not None else float("inf")
    return out
