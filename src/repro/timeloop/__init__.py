"""Analytical accelerator cost model (Timeloop-style), reimplemented from scratch.

The model follows the abstractions of Parashar et al. (ISPASS 2019) as used by the
paper: a 7-level conv loop nest is mapped onto a 3-level storage hierarchy
(DRAM -> global buffer -> per-PE local buffers) with a 2D spatial PE array in
between.  Energy is per-level access counts times a per-level energy table; delay
is the max of compute and per-level bandwidth bottlenecks; the objective is the
energy-delay product (EDP).
"""

from repro.timeloop.workloads import (ConvLayer, PAPER_WORKLOADS,
                                      MODEL_LAYERS, SAMPLER_DIVISOR_CAP,
                                      divisors, sampler_divisors)
from repro.timeloop.arch import HardwareConfig, EnergyTable, hw_is_valid
from repro.timeloop.mapping import (Mapping, mapping_is_valid, random_mapping,
                                    sample_constrained_batch)
from repro.timeloop.model import evaluate, Evaluation
from repro.timeloop.batch import (MappingBatch, evaluate_batch, features_batch,
                                  pack, sample_valid_pool, valid_batch)
from repro.timeloop.eyeriss import (
    eyeriss_168,
    eyeriss_256,
    eyeriss_baseline_edp,
    baseline_mapper,
)

__all__ = [
    "ConvLayer",
    "PAPER_WORKLOADS",
    "MODEL_LAYERS",
    "SAMPLER_DIVISOR_CAP",
    "divisors",
    "sampler_divisors",
    "HardwareConfig",
    "EnergyTable",
    "hw_is_valid",
    "Mapping",
    "mapping_is_valid",
    "random_mapping",
    "sample_constrained_batch",
    "evaluate",
    "Evaluation",
    "MappingBatch",
    "evaluate_batch",
    "features_batch",
    "pack",
    "sample_valid_pool",
    "valid_batch",
    "eyeriss_168",
    "eyeriss_256",
    "eyeriss_baseline_edp",
    "baseline_mapper",
]
