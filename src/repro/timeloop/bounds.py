"""Provable per-(hardware, layer) EDP lower bounds (the bound-and-prune pass).

The semi-decoupled co-design result (arXiv 2203.13921, PAPERS.md) rests on one
observation: most hardware candidates can be discarded by a cheap best-case
bound *before* any mapping search.  This module derives such a bound against
`model.evaluate` (the scalar ground truth): for EVERY mapping `m` that is
valid on `(hw, layer)`,

    lower_bound(hw, layer) <= evaluate(hw, m, layer).edp

so a candidate whose summed per-layer bound already exceeds the incumbent's
true model EDP provably cannot win the outer search, no matter what the inner
mapping optimizer would find.

Derivation (all level factors are >= 1; per-dim factors across the five levels
multiply exactly to the layer dim -- the mapping-validity factorization check):

  * trips:    `_level_trips` multiplies the level's relevant factors and any
              irrelevant factors ordered outside them, so
              trips >= prod(relevant factors at that level)   (and >= 1).
  * rw:       the output read-modify-write multiplier `2 * passes - 1 >= 1`.
  * spatial:  sp_all >= sp_rel (both products of factors >= 1).
  * tiles:    the W tile (r*s*c*k) and O tile (p*q*k) are plain products, so
              tile * (relevant spatial) * (relevant gb trips) * (relevant dram
              trips) >= product of ALL levels' factors over the tensor's
              relevant dims = weight_size / output_size exactly.  The I tile
              uses the halo extent ext(p, r) = (p - 1) * stride + r, and
              telescoping any per-level split of P (and R) keeps the product
              above touched(P, R) = min((P-1)*stride + R, P*R) -- the
              distinct input positions along that axis (the halo extent when
              strides overlap, P*R disjoint windows when stride > R leaves
              gaps; the full `input_size` = ext(P, R)*ext(Q, S)*C is NOT a
              valid bound in the gapped case).

Summing the three tensors therefore bounds every accumulator of
`model.evaluate` / `batch.evaluate_batch` by

    traffic_lb = weight_size + output_size
                 + C * touched(P, R) * touched(Q, S)

    gb_acc   >= traffic_lb          noc_acc  >= traffic_lb
    dram_acc >= traffic_lb          lb_acc   >= 4 * macs + traffic_lb

The compute roof is *mesh-divisibility aware*.  `used_pes = sp_x * sp_y`
where sp_x is a product of per-dim spatial factors, each dividing its layer
dim (the factorization check), with sp_x <= pe_mesh_x (mesh validity) -- so
sp_x can never exceed

    cap(mesh_x) = max{ prod_d g_d : g_d | dim(d) } <= mesh_x

over the dims available for spatial blocking (a dataflow pin df_fh == 2 /
df_fw == 2 fixes ALL of R / S inside the PE, removing that dim), and likewise
for sp_y.  `used_pes <= cap(mesh_x) * cap(mesh_y)` then bounds utilization by
what the layer's divisor structure lets the mesh shape actually host: a
168 = 24x7 mesh cannot be filled by power-of-two layer dims, and the bound
sees it.  (Bounding each axis separately is sound -- the joint per-dim split
constraint can only shrink the product further.)

The EDP bound follows from the model's own energy/delay formulas with every
accumulator replaced by its bound:

    energy_lb = macs * e_mac + (4 * macs + traffic_lb) * e_lb
                + traffic_lb * (e_noc + gb_access_energy + e_dram)
    delay_lb  = max(macs / (cap(mesh_x) * cap(mesh_y)),
                    traffic_lb / gb_bandwidth, traffic_lb / dram_bandwidth)
    edp_lb    = energy_lb * delay_lb

The bound is a roofline: it assumes perfect reuse (every word moved once),
best-achievable PE utilization, and no read-modify-write amplification, all of
which real mappings violate -- so it is loose in absolute terms but
*ordering-accurate* in the quantities that vary across the hardware pool
(mesh shape x layer divisibility, dataflow pins, gb_bandwidth,
gb_access_energy), which is what pruning needs.

`lower_bound` is the scalar reference; `hw_bound_vecs` / `layer_bound_vecs` /
`layer_caps` pack pools and layer stacks for the vectorized twins --
`batch.edp_lower_bounds_batch` (NumPy) and
`batch_jax.edp_lower_bounds_device` (one jitted dispatch) -- both
parity-pinned against the scalar here and property-tested against random
valid mappings in tests/test_bounds.py.  This module stays NumPy-only: the
default backend must not pay for the JAX import chain.
"""

from __future__ import annotations

import functools

import numpy as np

from repro.timeloop.arch import HardwareConfig
from repro.timeloop.workloads import DIMS, ConvLayer, divisors

# hw_bound_vecs column layout: the edp_reduce consts block (hw_vec[H_EMAC:] of
# `batch_jax`) with mesh shape and dataflow pins appended.
(B_EMAC, B_ELB, B_ENOC, B_EGB, B_EDRAM, B_GBBW, B_DRAMBW,
 B_MX, B_MY, B_DFW, B_DFH) = range(11)

# Divisor products above any real mesh axis are interchangeable with infinity;
# capping there keeps the per-layer tables tiny.
_CAP_LIMIT = 1 << 20


def _touched(outputs: int, filt: int, stride: int) -> int:
    """Distinct input positions along one axis: the halo extent
    (outputs-1)*stride + filt when strides overlap, outputs*filt disjoint
    windows when stride > filt leaves gaps."""
    return min((outputs - 1) * stride + filt, outputs * filt)


def traffic_lower_bound(layer: ConvLayer) -> float:
    """Minimum words any valid mapping moves through every memory level:
    weights + outputs once each, plus the distinct input words any valid
    mapping touches, C * touched(P,R) * touched(Q,S) -- at least P*Q*C, and
    strictly tighter whenever R or S exceeds 1."""
    input_lb = (_touched(layer.P, layer.R, layer.stride)
                * _touched(layer.Q, layer.S, layer.stride) * layer.C)
    return float(layer.weight_size + layer.output_size + input_lb)


def _divisor_products(dims_vals) -> np.ndarray:
    """Sorted achievable products prod_d g_d with g_d | dim_d (capped): the
    set of values a spatial factor product over these dims can take."""
    prods = {1}
    for dv in dims_vals:
        prods = {p * d for p in prods for d in divisors(dv)
                 if p * d <= _CAP_LIMIT} | prods
    return np.array(sorted(prods), dtype=np.float64)


@functools.lru_cache(maxsize=None)
def _caps_for(dims_key: tuple) -> tuple[np.ndarray, ...]:
    """The four dataflow variants' achievable-product tables for one layer's
    dims (keyed by the dim tuple so equal-shaped layers share).  Variant
    v = 2*(df_fh == 2) + (df_fw == 2): df_fh pins R inside the PE (no spatial
    R), df_fw pins S."""
    dims = dict(zip(DIMS, dims_key))
    out = []
    for pin_r in (False, True):
        for pin_s in (False, True):
            avail = [v for d, v in dims.items()
                     if not (d == "R" and pin_r) and not (d == "S" and pin_s)]
            out.append(_divisor_products(avail))
    # order: v0 (no pin), v1 (S pinned), v2 (R pinned), v3 (both)
    return tuple(out)


def spatial_caps(layer: ConvLayer) -> np.ndarray:
    """(4, A) sorted achievable spatial-product tables, one row per dataflow
    variant, rows padded (by repeating the row max) to a shared width."""
    tables = _caps_for(tuple(layer.dim(d) for d in DIMS))
    width = max(len(t) for t in tables)
    return np.stack([
        np.concatenate([t, np.full(width - len(t), t[-1])]) for t in tables
    ])


def used_pes_cap(hw: HardwareConfig, layer: ConvLayer) -> float:
    """Best-achievable PE count: cap(mesh_x) * cap(mesh_y) over the layer's
    divisor structure (scalar reference for the vectorized bound)."""
    v = 2 * (hw.df_fh == 2) + (hw.df_fw == 2)
    table = _caps_for(tuple(layer.dim(d) for d in DIMS))[v]
    ax = table[np.searchsorted(table, hw.pe_mesh_x, side="right") - 1]
    ay = table[np.searchsorted(table, hw.pe_mesh_y, side="right") - 1]
    return float(ax * ay)


def hw_bound_vec(hw: HardwareConfig) -> np.ndarray:
    """(11,) bound constants for one config (see B_* column layout)."""
    e = hw.energy
    return np.array(
        [e.mac, e.lb, e.noc, hw.gb_access_energy, e.dram,
         hw.gb_bandwidth, hw.dram_bandwidth,
         hw.pe_mesh_x, hw.pe_mesh_y, hw.df_fw, hw.df_fh],
        dtype=np.float64,
    )


def hw_bound_vecs(hws) -> np.ndarray:
    """(n, 11) stacked bound constants for a hardware pool."""
    return np.stack([hw_bound_vec(hw) for hw in hws])


def layer_bound_vec(layer: ConvLayer) -> np.ndarray:
    """(2,) layer constants: [macs, traffic_lb]."""
    return np.array([layer.macs, traffic_lower_bound(layer)], dtype=np.float64)


def layer_bound_vecs(layers) -> np.ndarray:
    """(L, 2) stacked layer constants for the pool x layers bound matrix."""
    return np.stack([layer_bound_vec(layer) for layer in layers])


def layer_caps(layers) -> np.ndarray:
    """(L, 4, A) stacked per-variant spatial-cap tables, layer rows padded (by
    repeating their max) to one shared width -- the vectorized twins select
    rows by each config's dataflow variant and take the largest entry <= each
    mesh axis."""
    tables = [spatial_caps(layer) for layer in layers]
    width = max(t.shape[1] for t in tables)
    return np.stack([
        np.concatenate(
            [t, np.repeat(t[:, -1:], width - t.shape[1], axis=1)], axis=1)
        for t in tables
    ])


def lower_bound(hw: HardwareConfig, layer: ConvLayer) -> float:
    """Scalar reference bound (see module docstring for the derivation)."""
    e = hw.energy
    macs = float(layer.macs)
    traffic = traffic_lower_bound(layer)
    energy = (macs * e.mac
              + (4.0 * macs + traffic) * e.lb
              + traffic * (e.noc + hw.gb_access_energy + e.dram))
    delay = max(macs / used_pes_cap(hw, layer),
                traffic / hw.gb_bandwidth,
                traffic / hw.dram_bandwidth)
    return energy * delay


def edp_lower_bounds(hws, layers) -> np.ndarray:
    """(n_hw, L) bound matrix over a hardware pool x layer stack (NumPy)."""
    from repro.timeloop.batch import edp_lower_bounds_batch

    return edp_lower_bounds_batch(
        hw_bound_vecs(hws), layer_bound_vecs(layers), layer_caps(layers))
