"""JAX backend for the batched mapping-evaluation protocol.

Drop-in twin of `repro.timeloop.batch` (the NumPy engine) over the same packed
encoding -- `MappingBatch.factors` int (B, 5, 6) plus (B, 6) loop-order
permutations -- with the whole per-trial pipeline traced into one jitted device
program:

  valid_batch      (B,) bool      validity masks (exact parity with NumPy)
  evaluate_batch   dict of (B,)   energy / delay / EDP / -log10(EDP) utility
  features_batch   (B, 14)        the BO surrogate's feature matrix
  forward_device   dict of jax.Array -- everything above, device-resident, for
                   fused GP-acquisition pool scoring (`core.bo` consumes this
                   through `SoftwareSpace.features_batch_device`)

Structure: per-mapping tile/validity/gather prep is a `jax.vmap` of
`_prep_one`; the inner trip-count/energy reduction is
`repro.kernels.edp_reduce` -- a Pallas kernel on accelerators, the same
numerics as a plain-`jnp` call on CPU (`mode="jnp"`, the default off-TPU) or
through the Pallas interpreter (`mode="interpret"`, exercised in CI).

Hardware and layer parameters enter as *arrays* (`hw_vec` / `layer_vec`), not
static arguments, so one compiled program serves every (hardware, layer) pair
the nested co-design search probes; pools are padded to power-of-two buckets so
the jit cache stays small across pool sizes.  Both vectors are carried *per
row* -- the rows of one batch may belong to different layers AND different
hardware configs -- which is what lets `forward_device_stacked` pack candidate
pools into a single stacked device program: all L layers of one hardware probe
(the layer-batched nested search, (L*B,) rows), or all H*L (probe, layer)
searches of the outer loop's warmup fan-out (`strategy="probe_fanout"`,
(H*L*B,) rows).  Either way it is the *same* jitted `_forward` program as the
single-layer path, so per-row results are identical.

Precision: the engine computes in float64 by default (scoped via
`jax.experimental.enable_x64` -- no global flag is touched), which keeps parity
with the NumPy engine at ~1e-12; pass `dtype="float32"` for accelerator runs
(on TPU, where x64 is unavailable, float32 is the default).

Backend selection from the search stack: `SoftwareSpace(..., backend="jax")`,
`codesign(..., backend="jax")`, `benchmarks/run.py --backend jax`, or the
`REPRO_BACKEND=jax` environment variable (see README).
"""

from __future__ import annotations

import contextlib
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.kernels.edp_reduce import edp_reduce, reduce_edp_terms
from repro.timeloop.arch import HardwareConfig
from repro.timeloop.batch import (
    D_R,
    D_S,
    L_DRAM,
    L_GB,
    L_LB,
    L_SX,
    L_SY,
    MappingBatch,
    REL_MASKS,
    TENSORS,
)
from repro.timeloop.mapping import LEVELS
from repro.timeloop.workloads import DIMS, ConvLayer

N_DIMS = len(DIMS)
N_LEVELS = len(LEVELS)

# (3, 6) relevance masks, tensors in TENSORS order (W, I, O), dims in DIMS order.
_REL = np.stack([REL_MASKS[t] for t in TENSORS]).astype(np.float64)

# hw_vec layout: validity bounds first, then energy/bandwidth constants.
(H_LBW, H_LBI, H_LBO, H_GBE, H_MX, H_MY, H_DFW, H_DFH,
 H_EMAC, H_ELB, H_ENOC, H_EGB, H_EDRAM, H_GBBW, H_DRAMBW) = range(15)
# layer_vec layout: the six loop extents (DIMS order), stride, macs.
L_STRIDE, L_MACS = 6, 7


def hw_vec(hw: HardwareConfig) -> np.ndarray:
    """Hardware constants as a (15,) float vector (see index constants above)."""
    e = hw.energy
    return np.array(
        [
            hw.lb_weight, hw.lb_input, hw.lb_output, hw.gb_entries,
            hw.pe_mesh_x, hw.pe_mesh_y, hw.df_fw, hw.df_fh,
            e.mac, e.lb, e.noc, hw.gb_access_energy, e.dram,
            hw.gb_bandwidth, hw.dram_bandwidth,
        ],
        dtype=np.float64,
    )


def layer_vec(layer: ConvLayer) -> np.ndarray:
    """Layer constants as an (8,) float vector: dims, stride, macs."""
    return np.array(
        [*(layer.dim(d) for d in DIMS), layer.stride, layer.macs],
        dtype=np.float64,
    )


def layer_vecs(layers) -> np.ndarray:
    """(L, 8) stacked layer vectors for the layer-batched forward."""
    return np.stack([layer_vec(layer) for layer in layers])


def hw_vecs(hws) -> np.ndarray:
    """(L, 15) stacked hardware vectors for the probe-stacked forward."""
    return np.stack([hw_vec(hw) for hw in hws])


def _prep_one(factors, order_gb, order_dram, hwv, layv):
    """Per-mapping tiles, validity, and gathered reduction operands.

    factors: (5, 6) float, orders: (6,) int, layv: (8,) -- one row of the
    packed pool (the layer vector is per-row so stacked multi-layer pools work).
    Returns (ok, fo (2,6), relo (2,3,6), tiles (2,3), sp (6,), sx, sy).
    All quantities entering the validity comparisons are < 2^24, so they are
    exact in float32 as well as float64 -- masks never depend on the dtype.
    """
    dims = layv[:N_DIMS]
    stride = layv[L_STRIDE]

    def ext(p, r):  # input halo extent, same formula as ConvLayer.input_extent
        return (p - 1.0) * stride + r

    def tiles(f):
        r, s, p, q, c, k = (f[i] for i in range(N_DIMS))
        return jnp.stack([r * s * c * k, ext(p, r) * ext(q, s) * c, p * q * k])

    lb = tiles(factors[L_LB])
    gbt = tiles(jnp.prod(factors[: L_GB + 1], axis=0))

    ok = jnp.all(jnp.prod(factors, axis=0) == dims)
    ok &= jnp.where(hwv[H_DFW] == 2.0, factors[L_LB, D_S] == dims[D_S], True)
    ok &= jnp.where(hwv[H_DFH] == 2.0, factors[L_LB, D_R] == dims[D_R], True)
    ok &= (lb[0] <= hwv[H_LBW]) & (lb[1] <= hwv[H_LBI]) & (lb[2] <= hwv[H_LBO])
    ok &= jnp.sum(gbt) <= hwv[H_GBE]
    sx = jnp.prod(factors[L_SX])
    sy = jnp.prod(factors[L_SY])
    ok &= (sx <= hwv[H_MX]) & (sy <= hwv[H_MY])

    rel = jnp.asarray(_REL, factors.dtype)  # (3, 6) compile-time constant
    sp = factors[L_SX] * factors[L_SY]      # (6,) per-dim spatial factors
    sp_rel = jnp.prod(jnp.where(rel > 0.5, sp[None, :], 1.0), axis=1)
    fo = jnp.stack([factors[L_GB][order_gb], factors[L_DRAM][order_dram]])
    relo = jnp.stack([rel[:, order_gb], rel[:, order_dram]])
    spv = jnp.concatenate(
        [sp_rel, jnp.stack([jnp.prod(sp), sx * sy, layv[L_MACS]])])
    return ok, fo, relo, jnp.stack([lb, gbt]), spv, sx, sy


@functools.partial(jax.jit, static_argnames=("mode",))
def _forward(factors, order_gb, order_dram, hwv, layv, mode: str):
    """The fused device program: validity + EDP + features for a whole pool.

    `hwv` is (B, 15) and `layv` is (B, 8) -- one hardware and one layer vector
    per row -- so a single compiled program serves the single-(hw, layer)
    path (rows share both), the layer-stacked path (rows span L layers), and
    the probe-stacked path (rows span H*L (hardware, layer) pairs).
    """
    ok, fo, relo, tl, spv, sx, sy = jax.vmap(
        _prep_one, in_axes=(0, 0, 0, 0, 0)
    )(factors, order_gb, order_dram, hwv, layv)

    consts = hwv[:, H_EMAC:]
    if mode == "jnp":
        ev, trips = reduce_edp_terms(fo, relo, tl, spv, consts)
    elif mode in ("pallas", "interpret"):
        ev, trips = edp_reduce(fo, relo, tl, spv, consts,
                               interpret=(mode == "interpret"))
    else:
        raise ValueError(f"mode must be jnp|pallas|interpret, got {mode!r}")

    energy, delay, edp = ev[:, 0], ev[:, 1], ev[:, 2]
    used = spv[:, 4]
    feats = jnp.stack(
        [
            tl[:, 0, 1] / hwv[:, H_LBI],
            tl[:, 0, 0] / hwv[:, H_LBW],
            tl[:, 0, 2] / hwv[:, H_LBO],
            jnp.sum(tl[:, 1, :], axis=1) / hwv[:, H_GBE],
            sx / hwv[:, H_MX],
            sy / hwv[:, H_MY],
            *[jnp.log1p(trips[:, j]) for j in range(2 * len(TENSORS))],
            jnp.log1p(used),
            jnp.log1p(layv[:, L_MACS] / used),
        ],
        axis=1,
    )
    inf = jnp.asarray(jnp.inf, energy.dtype)
    # Guard the log10 against invalid rows (inf EDP -> nan under where).
    utility = jnp.where(ok, -jnp.log10(jnp.where(ok, edp, 1.0)), -inf)
    return {
        "valid": ok,
        "energy_pj": jnp.where(ok, energy, inf),
        "delay_cycles": jnp.where(ok, delay, inf),
        "edp": jnp.where(ok, edp, inf),
        "utility": utility,
        "features": feats,
    }


def _bucket(n: int) -> int:
    b = 8
    while b < n:
        b *= 2
    return b


def _resolve(mode: str | None, dtype: str | None) -> tuple[str, str]:
    on_tpu = jax.default_backend() == "tpu"
    if mode is None:
        mode = "pallas" if on_tpu else "jnp"
    if dtype is None:
        dtype = "float32" if on_tpu else "float64"
    return mode, dtype


def forward_device(
    hw: HardwareConfig,
    mb: MappingBatch,
    layer: ConvLayer,
    mode: str | None = None,
    dtype: str | None = None,
) -> dict[str, jax.Array]:
    """Run the fused program; returns device-resident arrays (no host copy).

    `mode`: "jnp" (default off-TPU), "pallas" (default on TPU), or "interpret"
    (Pallas interpreter -- the kernel body, executed in Python).  `dtype`:
    "float64" (default off-TPU; scoped x64, parity with the NumPy engine) or
    "float32".
    """
    mode, dtype = _resolve(mode, dtype)
    B = len(mb)
    b = _bucket(B)
    # Benign padding rows: all-ones factors are invalid (factorization check)
    # but produce finite arithmetic everywhere (used_pes = 1, trips = 1).
    factors = np.ones((b, N_LEVELS, N_DIMS), np.int64)
    orders = np.tile(np.arange(N_DIMS, dtype=np.int32), (2, b, 1))
    if B:
        factors[:B] = mb.factors
        orders[0, :B] = mb.order_gb
        orders[1, :B] = mb.order_dram
    ctx = enable_x64() if dtype == "float64" else contextlib.nullcontext()
    with ctx:
        out = _forward(
            jnp.asarray(factors, dtype),
            jnp.asarray(orders[0], jnp.int32),
            jnp.asarray(orders[1], jnp.int32),
            jnp.asarray(np.broadcast_to(hw_vec(hw), (b, 15)), dtype),
            jnp.asarray(np.broadcast_to(layer_vec(layer), (b, 8)), dtype),
            mode=mode,
        )
    return {k: v[:B] for k, v in out.items()}


def forward_device_stacked(
    hw,
    pools,
    layers,
    mode: str | None = None,
    dtype: str | None = None,
) -> dict[str, jax.Array]:
    """Stacked fused program: L per-run pools, one device dispatch.

    `pools` is a sequence of L `MappingBatch`es (lengths may differ), `layers`
    the matching `ConvLayer`s, and `hw` either ONE `HardwareConfig` shared by
    every run (the layer-batched nested search) or a sequence of L per-run
    configs (the probe-fanout search, where the runs span H hardware probes).
    All pools are packed into one (L*bucket,)-row batch -- the hardware and
    layer vectors ride per row -- and evaluated by the *same* jitted
    `_forward` program as the single-layer path, so per-row results are
    identical to L separate `forward_device` calls.  Returns device-resident
    arrays with a leading (L, B) shape, B = max pool length (rows past a
    pool's own length are padding: invalid, -inf utility).
    """
    mode, dtype = _resolve(mode, dtype)
    L = len(pools)
    assert L == len(layers), (L, len(layers))
    hws = [hw] * L if isinstance(hw, HardwareConfig) else list(hw)
    assert L == len(hws), (L, len(hws))
    B = max((len(p) for p in pools), default=0)
    b = _bucket(B)
    factors = np.ones((L, b, N_LEVELS, N_DIMS), np.int64)
    orders = np.tile(np.arange(N_DIMS, dtype=np.int32), (2, L, b, 1))
    for k, p in enumerate(pools):
        n = len(p)
        if n:
            factors[k, :n] = p.factors
            orders[0, k, :n] = p.order_gb
            orders[1, k, :n] = p.order_dram
    layv = np.repeat(layer_vecs(layers)[:, None, :], b, axis=1)
    hwv = np.repeat(hw_vecs(hws)[:, None, :], b, axis=1)
    ctx = enable_x64() if dtype == "float64" else contextlib.nullcontext()
    with ctx:
        out = _forward(
            jnp.asarray(factors.reshape(L * b, N_LEVELS, N_DIMS), dtype),
            jnp.asarray(orders[0].reshape(L * b, N_DIMS), jnp.int32),
            jnp.asarray(orders[1].reshape(L * b, N_DIMS), jnp.int32),
            jnp.asarray(hwv.reshape(L * b, 15), dtype),
            jnp.asarray(layv.reshape(L * b, 8), dtype),
            mode=mode,
        )
    return {k: v.reshape(L, b, *v.shape[1:])[:, :B] for k, v in out.items()}


# --- EDP lower bounds (bound-and-prune pass) -------------------------------------

@jax.jit
def _lower_bounds(hwv, layb, caps):
    """(n, L) provable EDP lower bounds from (n, 15) hw vectors + (L, 2)
    [macs, traffic_lb] layer constants + (L, 4, A) sorted spatial-cap tables.
    Reuses the `hw_vec` plumbing of the fused forward: the energy/bandwidth
    block is the same `hwv[:, H_EMAC:]` consts slice `edp_reduce` consumes,
    and the mesh shape + dataflow pins select each config's best-achievable
    PE count from the cap tables.  Same formulas as `bounds.lower_bound` /
    `batch.edp_lower_bounds_batch` (derivation in `timeloop.bounds`)."""
    consts = hwv[:, H_EMAC:]
    e_mac, e_lb, e_noc, e_gb, e_dram, gb_bw, dram_bw = (
        consts[:, j:j + 1] for j in range(7))
    # dataflow variant per config: v = 2*(df_fh==2) + (df_fw==2)
    v = (2 * (hwv[:, H_DFH] == 2.0) + (hwv[:, H_DFW] == 2.0)).astype(jnp.int32)
    capsel = jnp.take(caps, v, axis=1)  # (L, n, A)
    mx, my = hwv[:, H_MX], hwv[:, H_MY]
    ax = jnp.max(jnp.where(capsel <= mx[None, :, None], capsel, 1.0), axis=-1)
    ay = jnp.max(jnp.where(capsel <= my[None, :, None], capsel, 1.0), axis=-1)
    used = (ax * ay).T  # (n, L) best-achievable PE count
    macs, traffic = layb[:, 0][None, :], layb[:, 1][None, :]
    energy = (macs * e_mac + (4.0 * macs + traffic) * e_lb
              + traffic * (e_noc + e_gb + e_dram))
    delay = jnp.maximum(macs / used,
                        jnp.maximum(traffic / gb_bw, traffic / dram_bw))
    return energy * delay


def edp_lower_bounds_device(hws, layers, dtype: str | None = None) -> np.ndarray:
    """(n_hw, L) bound matrix over a hardware pool x layer stack as ONE jitted
    dispatch -- the JAX twin of `bounds.edp_lower_bounds`, parity-pinned in
    tests/test_bounds.py.  The pool axis is padded to the shared power-of-two
    buckets (all-ones padding rows are benign: every bound input is >= 1, and
    an all-ones row selects variant 0 with unit mesh caps), so the compiled
    program is reused across pool sizes; results come back to the host, where
    the prune hook filters plain candidate lists."""
    from repro.timeloop.bounds import layer_bound_vecs, layer_caps

    _, dtype = _resolve(None, dtype)
    n = len(hws)
    b = _bucket(n)
    hwv = np.ones((b, 15), np.float64)
    if n:
        hwv[:n] = hw_vecs(hws)
    ctx = enable_x64() if dtype == "float64" else contextlib.nullcontext()
    with ctx:
        out = _lower_bounds(jnp.asarray(hwv, dtype),
                            jnp.asarray(layer_bound_vecs(layers), dtype),
                            jnp.asarray(layer_caps(layers), dtype))
    return np.asarray(out)[:n]


# --- host-facing twins of the NumPy engine -------------------------------------

def valid_batch(
    mb: MappingBatch, hw: HardwareConfig, layer: ConvLayer, **kw
) -> np.ndarray:
    """(B,) bool -- exact twin of `batch.valid_batch` / `mapping_is_valid`."""
    return np.asarray(forward_device(hw, mb, layer, **kw)["valid"])


def evaluate_batch(
    hw: HardwareConfig, mb: MappingBatch, layer: ConvLayer, **kw
) -> dict[str, np.ndarray]:
    """Twin of `batch.evaluate_batch` (plus a precomputed `utility` entry)."""
    out = forward_device(hw, mb, layer, **kw)
    return {k: np.asarray(v) for k, v in out.items() if k != "features"}


def features_batch(
    mb: MappingBatch, hw: HardwareConfig, layer: ConvLayer, **kw
) -> np.ndarray:
    """(B, 14) feature matrix -- twin of `batch.features_batch`."""
    return np.asarray(forward_device(hw, mb, layer, **kw)["features"])
