"""EDP evaluation of a (hardware, mapping, layer) triple.

Access counting follows the Timeloop temporal-reuse rule: a tensor tile resident
at level L is refetched from its parent once per iteration of every *relevant*
loop at the parent level, and once per iteration of every irrelevant loop that is
ordered OUTSIDE at least one relevant loop (irrelevant loops nested inside all
relevant loops reuse the tile).  Outputs are read-modify-write: when reduction
loops re-visit an output tile, traffic counts 2*passes - 1 (the first pass only
writes).

Energy  = macs*e_mac + lb*e_lb + noc*e_noc + gb*e_gb + dram*e_dram   [pJ]
Delay   = max(compute, gb_traffic/gb_bw, dram_traffic/dram_bw)       [cycles]
EDP     = energy * delay                                             [pJ*cycles]
"""

from __future__ import annotations

import dataclasses

from repro.timeloop.arch import HardwareConfig
from repro.timeloop.mapping import Mapping, gb_tiles, lb_tiles, mapping_is_valid
from repro.timeloop.workloads import DIMS, RELEVANCE, ConvLayer


@dataclasses.dataclass(frozen=True)
class Evaluation:
    energy_pj: float
    delay_cycles: float
    edp: float
    valid: bool
    reason: str
    breakdown: dict


def _level_trips(order: tuple[str, ...], factors: dict[str, int], relevant: frozenset) -> int:
    """Iterations at one temporal level that force a refetch of the child tile."""
    active = [d for d in order if factors.get(d, 1) > 1]
    if not any(d in relevant for d in active):
        return 1
    innermost_rel = max(i for i, d in enumerate(active) if d in relevant)
    trips = 1
    for i, d in enumerate(active):
        if d in relevant or i < innermost_rel:
            trips *= factors[d]
    return trips


def _passes(order: tuple[str, ...], factors: dict[str, int], tensor: str) -> int:
    """For outputs: number of reduction passes forced at this level (loops over
    reduction dims ordered outside the output-relevant loops)."""
    if tensor != "O":
        return 1
    rel = RELEVANCE["O"]
    active = [d for d in order if factors.get(d, 1) > 1]
    rel_positions = [i for i, d in enumerate(active) if d in rel]
    anchor = min(rel_positions) if rel_positions else len(active)
    passes = 1
    for i, d in enumerate(active):
        if d not in rel and i < anchor:
            passes *= factors[d]
    return passes


def evaluate(hw: HardwareConfig, m: Mapping, layer: ConvLayer) -> Evaluation:
    ok, reason = mapping_is_valid(m, hw, layer)
    if not ok:
        return Evaluation(float("inf"), float("inf"), float("inf"), False, reason, {})

    e = hw.energy
    macs = layer.macs
    used_pes = m.used_pes

    lb = lb_tiles(m, layer)
    gb = gb_tiles(m, layer)

    f_gb = {d: m.f("gb", d) for d in DIMS}
    f_dram = {d: m.f("dram", d) for d in DIMS}
    sp = {d: m.f("sx", d) * m.f("sy", d) for d in DIMS}

    lb_acc = 0.0
    noc_acc = 0.0
    gb_acc = 0.0
    dram_acc = 0.0

    for t in ("W", "I", "O"):
        rel = RELEVANCE[t]
        # Refetches of the per-PE LB tile from the GB, per GB-tile residency.
        gb_trips = _level_trips(m.order_gb, f_gb, rel)
        # Refetches of the GB tile from DRAM.
        dram_trips = _level_trips(m.order_dram, f_dram, rel)
        # Spatial multicast: PEs along spatially-unrolled *irrelevant* dims share
        # the same data -> one GB read feeds them all; relevant spatial dims need
        # distinct data per PE.
        sp_rel = 1
        sp_all = 1
        for d in DIMS:
            sp_all *= sp[d]
            if d in rel:
                sp_rel *= sp[d]

        fills_lb = lb[t] * gb_trips * dram_trips  # per spatial instance group
        rw = 1.0
        if t == "O":
            gb_passes = _passes(m.order_gb, f_gb, t)
            rw = 2.0 * gb_passes - 1.0
        gb_acc += fills_lb * sp_rel * rw
        noc_acc += fills_lb * sp_all * rw
        lb_acc += fills_lb * sp_all * rw  # writes into LB on fill / drain

        fills_gb = gb[t] * dram_trips
        rw_d = 1.0
        if t == "O":
            dram_passes = _passes(m.order_dram, f_dram, t)
            rw_d = 2.0 * dram_passes - 1.0
        dram_acc += fills_gb * rw_d

    # Per-MAC operand traffic inside the PE (read W, read I, RMW O).
    lb_acc += 4.0 * macs

    energy = (
        macs * e.mac
        + lb_acc * e.lb
        + noc_acc * e.noc
        + gb_acc * hw.gb_access_energy
        + dram_acc * e.dram
    )

    compute_cycles = macs / used_pes
    gb_cycles = gb_acc / hw.gb_bandwidth
    dram_cycles = dram_acc / hw.dram_bandwidth
    delay = max(compute_cycles, gb_cycles, dram_cycles)
    edp = energy * delay

    return Evaluation(
        energy_pj=energy,
        delay_cycles=delay,
        edp=edp,
        valid=True,
        reason="ok",
        breakdown={
            "macs": macs,
            "used_pes": used_pes,
            "lb_accesses": lb_acc,
            "noc_accesses": noc_acc,
            "gb_accesses": gb_acc,
            "dram_accesses": dram_acc,
            "compute_cycles": compute_cycles,
            "gb_cycles": gb_cycles,
            "dram_cycles": dram_cycles,
        },
    )
