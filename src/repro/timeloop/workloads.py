"""Neural-layer workloads from the paper (Fig. 11 / Fig. 12).

Every layer -- conv, FC, or attention projection -- is expressed in the canonical
7-level conv form used by Timeloop:

    R, S : filter height / width
    P, Q : output height / width
    C    : input channels
    K    : output channels
    (N = 1 throughout, as in the paper's inference setting)

FC layers map d_in -> C, d_out -> K, and the token/batch dimension -> P (this is
the standard Timeloop encoding of a GEMM as a 1x1 convolution).
"""

from __future__ import annotations

import dataclasses
import functools
import math
import warnings

DIMS = ("R", "S", "P", "Q", "C", "K")

# Tensor relevance: which loop dims index each operand.
RELEVANCE = {
    "W": frozenset({"R", "S", "C", "K"}),
    "I": frozenset({"R", "S", "P", "Q", "C"}),
    "O": frozenset({"P", "Q", "K"}),
}


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    name: str
    R: int
    S: int
    P: int
    Q: int
    C: int
    K: int
    stride: int = 1

    def dim(self, d: str) -> int:
        return getattr(self, d)

    @property
    def macs(self) -> int:
        return self.R * self.S * self.P * self.Q * self.C * self.K

    def input_extent(self, p: int, r: int) -> int:
        """Input halo extent covering `p` outputs with filter extent `r`."""
        return (p - 1) * self.stride + r

    @property
    def input_size(self) -> int:
        return (
            self.input_extent(self.P, self.R)
            * self.input_extent(self.Q, self.S)
            * self.C
        )

    @property
    def weight_size(self) -> int:
        return self.R * self.S * self.C * self.K

    @property
    def output_size(self) -> int:
        return self.P * self.Q * self.K

    def divisors(self, d: str) -> list[int]:
        return list(divisors(self.dim(d)))


def fc(name: str, d_in: int, d_out: int, tokens: int) -> ConvLayer:
    """FC / projection layer in conv form (tokens -> P)."""
    return ConvLayer(name=name, R=1, S=1, P=tokens, Q=1, C=d_in, K=d_out, stride=1)


# --- Paper workloads (Fig. 11) ------------------------------------------------
# ResNet-18 critical 3x3 layers; DQN conv layers.
_RESNET = [
    ConvLayer("ResNet-K1", R=3, S=3, P=56, Q=56, C=64, K=64, stride=2),
    ConvLayer("ResNet-K2", R=3, S=3, P=28, Q=28, C=128, K=128, stride=1),
    ConvLayer("ResNet-K3", R=3, S=3, P=14, Q=14, C=256, K=256, stride=1),
    ConvLayer("ResNet-K4", R=3, S=3, P=7, Q=7, C=512, K=512, stride=1),
]
_DQN = [
    ConvLayer("DQN-K1", R=8, S=8, P=20, Q=20, C=4, K=16, stride=4),
    ConvLayer("DQN-K2", R=4, S=4, P=9, Q=9, C=16, K=32, stride=2),
]
# Fig. 12: MLP and Transformer projections. The paper evaluates single layers; we
# follow the standard Timeloop GEMM encoding with a 64-token tile mapped to P.
_TOKENS = 64
_MLP = [
    fc("MLP-K1", 512, 512, _TOKENS),
    fc("MLP-K2", 64, 1024, _TOKENS),
]
_TRANSFORMER = [
    fc("Transformer-K1", 512, 16 * 32, _TOKENS),  # h=16, d_k=32
    fc("Transformer-K2", 512, 8 * 64, _TOKENS),   # h=8,  d_k=64
    fc("Transformer-K3", 512, 4 * 128, _TOKENS),  # h=4,  d_k=128
    fc("Transformer-K4", 512, 1 * 512, _TOKENS),  # h=1,  d_k=512
]

MODEL_LAYERS: dict[str, list[ConvLayer]] = {
    "resnet": _RESNET,
    "dqn": _DQN,
    "mlp": _MLP,
    "transformer": _TRANSFORMER,
}

PAPER_WORKLOADS: dict[str, ConvLayer] = {
    layer.name: layer for layers in MODEL_LAYERS.values() for layer in layers
}


def factorize(n: int) -> list[int]:
    """Prime factorization (with multiplicity) of n."""
    out = []
    d = 2
    while d * d <= n:
        while n % d == 0:
            out.append(d)
            n //= d
        d += 1
    if n > 1:
        out.append(n)
    return out


@functools.lru_cache(maxsize=4096)
def divisors(n: int) -> tuple[int, ...]:
    """Sorted divisors of n, memoized: the samplers call this O(pool x dims x
    levels) times per BO trial on a handful of distinct layer-dim values."""
    small, large = [], []
    for i in range(1, int(math.isqrt(n)) + 1):
        if n % i == 0:
            small.append(i)
            if i != n // i:
                large.append(n // i)
    return tuple(small + large[::-1])


# The zoo workload generator (repro.workloads.zoo) produces FC dims far outside
# the paper's (d_model 5120, vocab-shaped K ~ 2e5).  Their divisor *counts* are
# what the constrained samplers scale with -- every per-dim choice builds a
# (pool, n_divisors) candidate mask -- so a highly composite dim (e.g. 720720:
# 240 divisors) would quietly blow the sampler up.  `sampler_divisors` caps the
# ladder the samplers draw from; every paper and zoo dim today sits under the
# cap, so the guard only fires on genuinely pathological shapes.
SAMPLER_DIVISOR_CAP = 128


@functools.lru_cache(maxsize=4096)
def sampler_divisors(n: int) -> tuple[int, ...]:
    """Divisor ladder for the mapping samplers: identical to `divisors(n)` up
    to `SAMPLER_DIVISOR_CAP` entries; beyond that, a geometric subsample that
    always keeps 1 and n (so factor chains still terminate: the outermost
    level absorbs whatever remainder the sampled factors leave).  Any divisor
    subset yields structurally valid mappings -- capping only narrows the
    sampled tilings -- and the cap is announced loudly, once per dim."""
    ds = divisors(n)
    if len(ds) <= SAMPLER_DIVISOR_CAP:
        return ds
    warnings.warn(
        f"dim {n} has {len(ds)} divisors (> SAMPLER_DIVISOR_CAP="
        f"{SAMPLER_DIVISOR_CAP}); the mapping samplers draw from a geometric "
        f"subsample of {SAMPLER_DIVISOR_CAP} divisors (1 and {n} kept), so "
        "some tilings of this dim are unreachable", RuntimeWarning,
        stacklevel=2)
    idx = {round(i * (len(ds) - 1) / (SAMPLER_DIVISOR_CAP - 1))
           for i in range(SAMPLER_DIVISOR_CAP)}
    return tuple(ds[i] for i in sorted(idx))
