"""AdamW with dtype policies, global-norm clipping, and optional int8 gradient
compression for the cross-pod all-reduce (distributed-optimization trick)."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"      # "bfloat16" => pure-bf16 moments (400B fit)
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(cfg: AdamWConfig, params):
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def compress_int8(grads):
    """Blockwise int8 quantization of gradients (per-leaf absmax scale).
    Used to halve/quarter cross-pod all-reduce bytes; the all-reduce itself sums
    dequantized values, so this composes with any reduction."""
    def q(g):
        scale = jnp.max(jnp.abs(g.astype(jnp.float32))) / 127.0 + 1e-12
        return (jnp.round(g.astype(jnp.float32) / scale).astype(jnp.int8), scale)

    return jax.tree.map(q, grads, is_leaf=lambda x: isinstance(x, jnp.ndarray))


def decompress_int8(qgrads):
    return jax.tree.map(lambda t: t[0].astype(jnp.float32) * t[1], qgrads,
                        is_leaf=lambda x: isinstance(x, tuple))


def apply_updates(cfg: AdamWConfig, params, opt_state, grads):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    sdt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu32 = mu.astype(jnp.float32) * b1 + (1 - b1) * g32
        nu32 = nu.astype(jnp.float32) * b2 + (1 - b2) * g32 * g32
        mu_hat = mu32 / (1 - b1 ** step.astype(jnp.float32))
        nu_hat = nu32 / (1 - b2 ** step.astype(jnp.float32))
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), mu32.astype(sdt), nu32.astype(sdt)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(opt_state["mu"])
    flat_nu = tdef.flatten_up_to(opt_state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, {"grad_norm": gnorm, "lr": lr}
