"""xLSTM 1.3B [arXiv:2405.04517]: 48 blocks, d_model 2048, mLSTM:sLSTM 7:1.

d_ff=0 per the assignment: xLSTM blocks carry their own up/down projections
(mLSTM pre-up-projection x2, sLSTM post-up-projection 4/3) instead of a separate
FFN.  4 heads with GQA kv=4 (i.e. MHA at the memory level).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    # xLSTM[7:1]: one sLSTM block per 7 mLSTM blocks, period 8 (48 = 6 * 8).
    block_pattern=("mlstm",) * 7 + ("slstm",),
    rope=False,
    mlstm_chunk=256,
)

SMOKE_CONFIG = ModelConfig(
    name="xlstm-smoke",
    family="ssm",
    num_layers=4,
    d_model=64,
    num_heads=2,
    num_kv_heads=2,
    d_ff=0,
    vocab_size=256,
    block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    rope=False,
    mlstm_chunk=16,
)
