"""Phi-3-medium 14B [arXiv:2404.14219]: dense RoPE SwiGLU GQA decoder."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=10,
    d_ff=17920,
    vocab_size=100352,
)

SMOKE_CONFIG = ModelConfig(
    name="phi3-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    d_ff=128,
    vocab_size=256,
)
