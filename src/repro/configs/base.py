"""Model / run configuration and the --arch registry."""

from __future__ import annotations

import dataclasses
import importlib
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # Block pattern, cycled over layers (period must divide num_layers).
    # kinds: "attn" | "moe" | "mlstm" | "slstm" | "rglru" | "local_attn"
    block_pattern: tuple = ("attn",)

    # attention details
    rope: bool = True
    mrope: bool = False              # M-RoPE (qwen2-vl): 3-section rotary
    qk_norm: bool = False
    local_window: int = 0            # window for "local_attn" blocks
    # recurrent details
    rglru_conv_width: int = 4
    mlstm_chunk: int = 256           # chunkwise-parallel mLSTM chunk length
    # moe details
    num_experts: int = 0
    top_k: int = 0
    # encoder-decoder
    encoder_layers: int = 0          # >0 -> enc-dec; decoder uses num_layers
    # modality frontend stub: "tokens" or "embeddings"
    input_mode: str = "tokens"

    # attention implementation: "flash" (chunked online-softmax; O(bq*bk) mem)
    # or "naive" (materialized S^2 scores; the un-optimized baseline)
    attn_impl: str = "flash"
    flash_block_q: int = 1024
    flash_block_k: int = 1024

    # numerics / memory policy
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    optimizer_dtype: str = "float32"  # "bfloat16" => pure-bf16 optimizer state
    kv_cache_dtype: str = "bfloat16"  # "int8" => quantized KV cache
    remat: str = "block"              # "none" | "block" (checkpoint each block)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_layers % len(self.block_pattern) == 0, (
            self.name, "block pattern period must divide num_layers")

    @property
    def sub_quadratic(self) -> bool:
        """True if no block attends globally with O(S^2) cost (long_500k rule).
        'moe' blocks carry full attention; 'local_attn' is windowed."""
        return all(k not in ("attn", "moe") for k in self.block_pattern)

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    def padded_vocab(self, multiple: int = 512) -> int:
        v = self.vocab_size
        return ((v + multiple - 1) // multiple) * multiple


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS: tuple[str, ...] = (
    "xlstm-1.3b",
    "recurrentgemma-9b",
    "phi3-medium-14b",
    "smollm-360m",
    "stablelm-12b",
    "qwen3-14b",
    "moonshot-v1-16b-a3b",
    "llama4-maverick-400b-a17b",
    "seamless-m4t-large-v2",
    "qwen2-vl-72b",
)

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCH_IDS}")
    mod = importlib.import_module(_MODULES[arch])
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    mod = importlib.import_module(_MODULES[arch])
    return mod.SMOKE_CONFIG


def cell_is_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """The assignment's skip rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "skipped: pure full attention is O(S^2) at 512k"
    return True, "ok"
