"""SeamlessM4T-large-v2 [arXiv:2308.11596]: encoder-decoder multimodal backbone.

The modality frontend (speech encoder frontend) is a STUB per the assignment:
`input_specs()` supplies precomputed frame embeddings (B, S_src, d_model) to the
text/unit encoder-decoder backbone implemented here (24 enc + 24 dec layers).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    num_layers=24,           # decoder layers
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,       # padded to a shardable multiple internally
    input_mode="embeddings",
    rope=False,              # learned/sinusoidal positions in the original; we
                             # use rope=False -> additive positional embedding
)

SMOKE_CONFIG = ModelConfig(
    name="seamless-smoke",
    family="encdec",
    num_layers=2,
    encoder_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    input_mode="embeddings",
    rope=False,
)
