"""Qwen2-VL-72B [arXiv:2409.12191]: M-RoPE decoder backbone, dynamic resolution.

The vision frontend (ViT + patch merger) is a STUB per the assignment:
`input_specs()` supplies precomputed patch/text embeddings plus 3-component
M-RoPE position ids (temporal, height, width).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    mrope=True,
    input_mode="embeddings",
    kv_cache_dtype="int8",   # 80L x 32k decode cache: int8 to fit HBM
)

SMOKE_CONFIG = ModelConfig(
    name="qwen2vl-smoke",
    family="vlm",
    num_layers=2,
    d_model=96,
    num_heads=6,
    num_kv_heads=2,
    d_ff=192,
    vocab_size=256,
    mrope=True,
    input_mode="embeddings",
)
