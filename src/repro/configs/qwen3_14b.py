"""Qwen3-14B [hf:Qwen/Qwen3-14B]: dense GQA decoder with qk-norm."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=17408,
    vocab_size=151936,
    qk_norm=True,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen3-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    qk_norm=True,
)
