"""SmolLM-360M [hf:HuggingFaceTB/SmolLM-360M]: llama-architecture small model."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
)

SMOKE_CONFIG = ModelConfig(
    name="smollm-smoke",
    family="dense",
    num_layers=2,
    d_model=60,      # keeps the 15-head/4-per-head flavour at tiny scale
    num_heads=3,
    num_kv_heads=1,
    d_ff=160,
    vocab_size=256,
)
