"""RecurrentGemma-9B / Griffin [arXiv:2402.19427]: RG-LRU + local attention, 1:2.

38 layers isn't divisible by the 3-block Griffin period (rglru, rglru, local);
the published model runs the pattern cyclically with the tail truncated.  We use
period 2 x (rglru, rglru, local_attn) groups... 38 = 12*3 + 2: to keep the
scan-over-superblocks exact we follow the paper's repeating unit and pad the
layer count to the nearest multiple in the SMOKE config only; for the full
config we use 36 pattern layers + 2 trailing rglru layers folded as one extra
period of (rglru, rglru) -- expressed here as pattern period 19 over 38 layers:
(rglru, rglru, local) * 6 + (rglru,) -- exact for 38 = 2 * 19.
"""

from repro.configs.base import ModelConfig

_PATTERN = (("rglru", "rglru", "local_attn") * 6 + ("rglru",))  # 19 blocks; 38 = 2*19

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,      # MQA in the local-attention blocks
    d_ff=12288,
    vocab_size=256000,
    block_pattern=_PATTERN,
    rope=True,
    local_window=2048,
    rglru_conv_width=4,
)

SMOKE_CONFIG = ModelConfig(
    name="recurrentgemma-smoke",
    family="hybrid",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    d_ff=128,
    vocab_size=256,
    block_pattern=("rglru", "rglru", "local_attn"),
    rope=True,
    local_window=16,
    rglru_conv_width=4,
)
