"""Moonshot/Moonlight 16B-A3B [hf:moonshotai/Moonlight-16B-A3B]: MoE 64e top-6."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,            # per-expert FFN width
    vocab_size=163840,
    block_pattern=("moe",),
    num_experts=64,
    top_k=6,
)

SMOKE_CONFIG = ModelConfig(
    name="moonshot-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=64,
    vocab_size=256,
    block_pattern=("moe",),
    num_experts=8,
    top_k=2,
)
