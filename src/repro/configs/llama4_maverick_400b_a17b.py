"""Llama-4 Maverick 400B-A17B [arXiv/unverified]: interleaved MoE (1 dense : 1
MoE per pair), 128 routed experts top-1.  ~400B total / ~17B active.

Pure-bf16 optimizer state + bf16 params so that train-state bytes/device fit
v5e HBM at 256 chips (see DESIGN.md §Hardware-adaptation).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    block_pattern=("attn", "moe"),   # early-fusion interleaved MoE
    num_experts=128,
    top_k=1,
    param_dtype="bfloat16",
    optimizer_dtype="bfloat16",
)

SMOKE_CONFIG = ModelConfig(
    name="llama4-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab_size=256,
    block_pattern=("attn", "moe"),
    num_experts=8,
    top_k=1,
)
