"""Step-function builders (train / prefill / decode) plus their sharding specs.
Shared by the dry-run, the trainer, and the server."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.model import build_model, cache_specs, input_specs
from repro.optim import adamw
from repro.parallel import sharding


def _dp_if_divides(mesh, rules, size: int):
    """The batch axes, dropped when the batch dim doesn't divide them."""
    dp = sharding._filter_spec(mesh, (rules.batch,))[0]
    if dp is None:
        return None
    axes = dp if isinstance(dp, tuple) else (dp,)
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    return dp if size % total == 0 else None


def batch_sharding(cfg: ModelConfig, shape: ShapeConfig, mesh, rules):
    """NamedShardings for the data batch: batch dim over (pod, data)."""
    specs = {}
    for name, sds in input_specs(cfg, shape).items():
        bdim = 1 if name == "positions" else 0  # positions: (3, B, S)
        dp = _dp_if_divides(mesh, rules, sds.shape[bdim]) if sds.ndim > bdim else None
        spec = [None] * sds.ndim
        if sds.ndim > bdim:
            spec[bdim] = dp
        specs[name] = NamedSharding(mesh, sharding._filter_spec(mesh, tuple(spec)))
    return specs


def cache_sharding(cfg: ModelConfig, shape: ShapeConfig, mesh, rules):
    """Shardings for the decode cache: dim1 = batch; long KV length axes go to
    the model axis (sequence-sharded cache) when divisible."""
    dp = rules.batch

    def leaf_spec(leaf):
        dims = [None] * leaf.ndim
        if leaf.ndim >= 3:
            dims[1] = _dp_if_divides(mesh, rules, leaf.shape[1])  # (n_super, B, ...)
            # k/v caches: (n_super, B, S, KV, hd) -> shard S over model
            kv_axis = rules.kv_len if rules.kv_len is not None else "model"
            if leaf.ndim >= 5 and leaf.shape[2] % mesh.shape.get(kv_axis, 1) == 0:
                dims[2] = kv_axis
        return NamedSharding(mesh, sharding._filter_spec(mesh, tuple(dims)))

    cs = cache_specs(cfg, shape)
    if cfg.family == "encdec":
        cache, enc = cs
        enc_dp = _dp_if_divides(mesh, rules, enc.shape[0])
        enc_shd = NamedSharding(mesh, sharding._filter_spec(mesh, (enc_dp, None, None)))
        return (jax.tree.map(leaf_spec, cache), enc_shd)
    return jax.tree.map(leaf_spec, cs)


def state_shardings(model, mesh, rules, opt: bool = True):
    pshapes = model.param_shapes()
    pspecs = sharding.tree_param_specs(pshapes, mesh, rules)
    if not opt:
        return pspecs
    return {
        "params": pspecs,
        "opt": {
            "mu": pspecs,
            "nu": pspecs,
            "step": NamedSharding(mesh, P()),
        },
    }


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig):
    model = build_model(cfg)

    def train_step(state, batch):
        loss, grads = jax.value_and_grad(model.loss)(state["params"], batch)
        new_params, new_opt, metrics = adamw.apply_updates(
            opt_cfg, state["params"], state["opt"], grads)
        metrics = dict(metrics, loss=loss)
        return {"params": new_params, "opt": new_opt}, metrics

    return model, train_step


def make_prefill_step(cfg: ModelConfig):
    model = build_model(cfg)

    def prefill_step(params, batch):
        return model.prefill(params, batch)

    return model, prefill_step


def make_decode_step(cfg: ModelConfig):
    model = build_model(cfg)

    def serve_step(params, cache, batch, pos):
        return model.decode_step(params, cache, batch, pos)

    return model, serve_step


def init_train_state(model, cfg: ModelConfig, opt_cfg: adamw.AdamWConfig, key):
    params = model.init(key)
    return {"params": params, "opt": adamw.init_state(opt_cfg, params)}
