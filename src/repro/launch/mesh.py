"""Production mesh construction.

Importing this module never touches jax device state; meshes are built inside
functions only.  The production target is TPU v5e: 16x16 = 256 chips per pod,
2 pods = 512 chips for the multi-pod dry-run.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh_for(devices: int, model_parallel: int = 16):
    """Elastic variant: biggest (data, model) mesh for `devices` devices."""
    model = min(model_parallel, devices)
    while devices % model:
        model -= 1
    return jax.make_mesh(
        (devices // model, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


# v5e hardware constants for the roofline (per chip).
PEAK_FLOPS_BF16 = 197e12     # FLOP/s
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link
