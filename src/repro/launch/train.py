"""Training driver: real steps on the local device(s), with checkpoints,
fault-tolerant restart, straggler monitoring, and the synthetic data pipeline.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt

On a cluster the same driver runs under the production mesh (--mesh auto);
in this container it defaults to single-device with reduced dims (--smoke).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig, get_config, get_smoke_config
from repro.data.pipeline import DataConfig, SyntheticSource
from repro.launch import steps as S
from repro.optim import adamw
from repro.runtime.fault_tolerance import ResilientLoop


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    opt_cfg = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps,
                                warmup_steps=max(args.steps // 20, 10),
                                state_dtype=cfg.optimizer_dtype)

    model, train_step = S.make_train_step(cfg, opt_cfg)
    jstep = jax.jit(train_step, donate_argnums=(0,))
    state = S.init_train_state(model, cfg, opt_cfg, jax.random.key(args.seed))
    n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"batch={args.batch}x{args.seq} steps={args.steps}")

    source = SyntheticSource(cfg, shape, DataConfig(seed=args.seed))

    def step_fn(state, batch):
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        state, metrics = jstep(state, jb)
        return state, {k: float(v) for k, v in metrics.items()}

    losses = []

    def log(m):
        if "loss" in m:
            losses.append(m["loss"])
            if m["step"] % args.log_every == 0:
                print(f"step {m['step']:5d} loss {m['loss']:.4f} "
                      f"gnorm {m['grad_norm']:.3f} lr {m['lr']:.2e} "
                      f"{m['dt']*1e3:.0f}ms{' STRAGGLER' if m.get('straggler') else ''}")
        else:
            print(m)

    loop = ResilientLoop(step_fn, source, args.ckpt_dir, save_every=args.save_every)
    t0 = time.time()
    state, step, mlog, monitor = loop.run(state, 0, args.steps, log=log)
    dt = time.time() - t0
    print(f"done: {step} steps in {dt:.0f}s | first loss {losses[0]:.4f} "
          f"last loss {losses[-1]:.4f} | stragglers flagged {monitor.flagged}")
    return losses


if __name__ == "__main__":
    main()
