"""Serving driver: batched prefill + decode with a request queue.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
        --requests 16 --prompt-len 64 --gen-len 32

Implements continuous batched decoding over a fixed batch of slots: requests
are admitted into free slots after their (batched) prefill, decode steps run
for the whole batch, finished requests free their slot.  KV caches follow the
config's dtype policy (int8 supported).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, get_smoke_config
from repro.models.model import build_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray      # (prompt_len,)
    gen_len: int
    out_tokens: list = dataclasses.field(default_factory=list)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family == "encdec" or cfg.input_mode == "embeddings":
        raise SystemExit("serve.py demo drives token-in/token-out archs; "
                         "use launch/dryrun.py for the stub-frontend archs")
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    B = args.batch
    S_max = args.prompt_len + args.gen_len
    # round up so flash/mlstm chunk divisibility holds
    S_max = ((S_max + 63) // 64) * 64

    rng = np.random.default_rng(args.seed)
    pending = [Request(i, rng.integers(0, cfg.vocab_size, args.prompt_len),
                       args.gen_len) for i in range(args.requests)]
    done: list[Request] = []

    jprefill = jax.jit(model.prefill)
    jdecode = jax.jit(model.decode_step, donate_argnums=(1,))

    t0 = time.time()
    decode_steps = 0
    while pending or done is None:
        batch_reqs = pending[:B]
        pending = pending[B:]
        if not batch_reqs:
            break
        while len(batch_reqs) < B:   # pad the batch with a dummy copy
            batch_reqs.append(Request(-1, batch_reqs[0].prompt, batch_reqs[0].gen_len))
        prompts = np.stack([r.prompt for r in batch_reqs])
        # right-pad prompts to a chunk-friendly length
        P = ((args.prompt_len + 63) // 64) * 64
        toks = np.zeros((B, P), np.int32)
        toks[:, :args.prompt_len] = prompts
        logits, cache = jprefill(params, {"tokens": jnp.asarray(toks)})
        # NOTE: cache is sized to the prefill length; decode continues into a
        # fresh cache of S_max by re-prefilling the concatenation -- for the
        # demo we instead allocate the full cache via prefill on S_max window.
        toks_full = np.zeros((B, S_max), np.int32)
        toks_full[:, :args.prompt_len] = prompts
        logits, cache = jprefill(params, {"tokens": jnp.asarray(toks_full)})
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        pos = args.prompt_len
        for step in range(args.gen_len):
            for i, r in enumerate(batch_reqs):
                r.out_tokens.append(int(next_tok[i]))
            logits, cache = jdecode(params, cache,
                                    {"tokens": next_tok[:, None]},
                                    jnp.asarray(pos, jnp.int32))
            next_tok = jnp.argmax(logits[:, 0, :cfg.vocab_size], axis=-1).astype(jnp.int32)
            pos += 1
            decode_steps += 1
        done.extend(r for r in batch_reqs if r.rid >= 0)
    dt = time.time() - t0
    tok_s = decode_steps * B / dt if dt > 0 else 0.0
    print(f"served {len(done)} requests, {decode_steps} decode steps, "
          f"{dt:.1f}s, {tok_s:.1f} tok/s (batched)")
    for r in done[:3]:
        print(f"  req {r.rid}: first tokens {r.out_tokens[:8]}")
    return done


if __name__ == "__main__":
    main()
