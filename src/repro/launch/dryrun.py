import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on the
production meshes, prove memory fits, and extract the roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch phi3-medium-14b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all            # 40 cells, 16x16
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod

The XLA_FLAGS line above MUST run before any other jax import anywhere
(jax locks the device count at first init), which is why it is the first
statement of the module.
"""

import argparse
import json
import re
import time

import jax
import jax.numpy as jnp

from repro.configs.base import (ARCH_IDS, SHAPES, ModelConfig, ShapeConfig,
                                cell_is_applicable, get_config)
from repro.launch import steps as S
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.models.model import build_model, cache_specs, input_specs
from repro.optim import adamw
from repro.parallel import sharding

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"=\s*((?:\(|)[a-z0-9]+\[[^=]*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done|)\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in an (SPMD, per-device)
    HLO module, keyed by op kind.  `-start` ops counted, `-done` skipped."""
    out: dict[str, int] = {}
    count: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue
        kind = m.group(2)
        b = _shape_bytes(m.group(1))
        out[kind] = out.get(kind, 0) + b
        count[kind] = count.get(kind, 0) + 1
    out["total"] = sum(v for k, v in out.items() if k != "total")
    out["ops"] = sum(count.values())
    return out


def count_params(cfg: ModelConfig) -> tuple[float, float]:
    """(total, active) parameter counts from the param tree shapes."""
    model = build_model(cfg)
    shapes = model.param_shapes()
    paths, _ = jax.tree_util.tree_flatten_with_path(shapes)
    total = active = 0.0
    for path, leaf in paths:
        p = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path).lower()
        n = 1.0
        for d in leaf.shape:
            n *= d
        total += n
        if "expert" in p and cfg.num_experts:
            active += n * (cfg.top_k / cfg.num_experts)
        else:
            active += n
    return total, active


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Useful-work FLOPs for the cell (global): 6*N_active*tokens for training,
    2*N_active*tokens for inference."""
    _, active = count_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    return 2.0 * active * shape.global_batch  # decode: one token per sequence


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
               rules: sharding.AxisRules | None = None, extra_opt=None):
    """Build + lower the right step function for one cell. Returns lowered."""
    rules = rules or sharding.AxisRules()
    opt_cfg = extra_opt or adamw.AdamWConfig(state_dtype=cfg.optimizer_dtype)
    specs = input_specs(cfg, shape)
    with sharding.use_mesh(mesh, rules):
        if shape.kind == "train":
            model, train_step = S.make_train_step(cfg, opt_cfg)
            state_shapes = jax.eval_shape(
                lambda k: S.init_train_state(model, cfg, opt_cfg, k), jax.random.key(0))
            state_shd = S.state_shardings(model, mesh, rules)
            batch_shd = S.batch_sharding(cfg, shape, mesh, rules)
            jf = jax.jit(train_step,
                         in_shardings=(state_shd, batch_shd),
                         out_shardings=(state_shd, None),
                         donate_argnums=(0,))
            return jf.lower(state_shapes, specs)
        if shape.kind == "prefill":
            model, prefill_step = S.make_prefill_step(cfg)
            pshapes = model.param_shapes()
            pshd = S.state_shardings(model, mesh, rules, opt=False)
            batch_shd = S.batch_sharding(cfg, shape, mesh, rules)
            jf = jax.jit(prefill_step, in_shardings=(pshd, batch_shd))
            return jf.lower(pshapes, specs)
        # decode
        model, serve_step = S.make_decode_step(cfg)
        pshapes = model.param_shapes()
        pshd = S.state_shardings(model, mesh, rules, opt=False)
        cshapes = cache_specs(cfg, shape)
        cshd = S.cache_sharding(cfg, shape, mesh, rules)
        batch_shd = S.batch_sharding(cfg, shape, mesh, rules)
        jf = jax.jit(serve_step,
                     in_shardings=(pshd, cshd, batch_shd, None),
                     out_shardings=(None, cshd),
                     donate_argnums=(1,))
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        return jf.lower(pshapes, cshapes, specs, pos)


def _cost_triple(lowered_or_compiled) -> tuple[float, float, float]:
    compiled = lowered_or_compiled
    ca = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    return (float(ca.get("flops", 0.0)),
            float(ca.get("bytes accessed", 0.0)),
            float(coll.get("total", 0)))


def _depth_variant(cfg: ModelConfig, n_periods: int) -> ModelConfig:
    import dataclasses
    period = len(cfg.block_pattern)
    kw = {"num_layers": period * n_periods}
    if cfg.encoder_layers:
        kw["encoder_layers"] = n_periods
        kw["num_layers"] = n_periods
    return dataclasses.replace(cfg, **kw)


def extrapolated_costs(cfg: ModelConfig, shape: ShapeConfig, mesh,
                       rules=None) -> dict:
    """XLA counts while-loop bodies once (scan undercount); recover full-depth
    HLO flops/bytes/collective-bytes by lowering depth-1 and depth-2 variants
    with ALL scans unrolled (layer scan + flash/mlstm chunk loops) and
    extrapolating linearly (exact for any cost affine in depth).  The sLSTM
    per-timestep scan stays rolled (unrolling 4k steps is infeasible); its
    undercounted recurrent matmuls are ~1/num_heads of that block's FLOPs
    (documented in models/flops.py)."""
    from repro.models import layers as L
    L.ANALYSIS_UNROLL = True
    try:
        c1 = _cost_triple(lower_cell(_depth_variant(cfg, 1), shape, mesh, rules).compile())
        c2 = _cost_triple(lower_cell(_depth_variant(cfg, 2), shape, mesh, rules).compile())
    finally:
        L.ANALYSIS_UNROLL = False
    n = (cfg.num_layers // len(cfg.block_pattern)
         if not cfg.encoder_layers else cfg.num_layers)
    return {
        "flops_dev": c1[0] + (n - 1) * (c2[0] - c1[0]),
        "bytes_dev": c1[1] + (n - 1) * (c2[1] - c1[1]),
        "coll_dev": c1[2] + (n - 1) * (c2[2] - c1[2]),
        "depth1": c1, "depth2": c2, "n_periods": n,
    }


def analyze(lowered, cfg: ModelConfig, shape: ShapeConfig, mesh,
            rules=None, extrapolate: bool = True) -> dict:
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    ma = compiled.memory_analysis()
    n_dev = mesh.devices.size

    raw_flops, raw_bytes, raw_coll = _cost_triple(compiled)
    if extrapolate:
        ext = extrapolated_costs(cfg, shape, mesh, rules)
        flops_dev, bytes_dev, coll_dev = ext["flops_dev"], ext["bytes_dev"], ext["coll_dev"]
    else:
        ext = None
        flops_dev, bytes_dev, coll_dev = raw_flops, raw_bytes, raw_coll

    from repro.models.flops import cell_bytes, cell_flops
    af = cell_flops(cfg, shape)
    analytic_hw_dev = af["expected_hw"] / n_dev
    model_par = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    ab = cell_bytes(cfg, shape, n_dev, model_par)

    compute_t = max(analytic_hw_dev, flops_dev) / PEAK_FLOPS_BF16
    memory_t = ab["bytes_per_dev"] / HBM_BW
    coll_t = coll_dev / ICI_BW
    terms = {"compute_s": compute_t, "memory_s": memory_t, "collective_s": coll_t}
    bound = max(terms, key=terms.get)
    step_t = max(terms.values())
    mfu = (af["useful"] / (PEAK_FLOPS_BF16 * n_dev)) / step_t if step_t > 0 else 0.0

    return {
        "arch": cfg.name,
        "shape": shape.name,
        "kind": shape.kind,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "devices": n_dev,
        "compile_s": round(compile_s, 1),
        "memory": {
            "args_bytes_per_dev": ma.argument_size_in_bytes,
            "temp_bytes_per_dev": ma.temp_size_in_bytes,
            "output_bytes_per_dev": ma.output_size_in_bytes,
            "total_gib_per_dev": round((ma.argument_size_in_bytes
                                        + ma.temp_size_in_bytes) / 2**30, 3),
            "fits_16g": (ma.argument_size_in_bytes + ma.temp_size_in_bytes) < 16 * 2**30,
        },
        "hlo_flops_per_dev": flops_dev,
        "hlo_bytes_per_dev_upper": bytes_dev,   # no-fusion upper bound
        "analytic_bytes_per_dev": ab["bytes_per_dev"],
        "collective_bytes_per_dev": coll_dev,
        "hlo_raw_per_dev": {"flops": raw_flops, "bytes": raw_bytes, "coll": raw_coll},
        "analytic_flops": af,
        "roofline": dict(terms, bound=bound, step_time_s=step_t),
        "useful_flops_ratio": (af["useful"] / (flops_dev * n_dev)) if flops_dev else 0.0,
        "mfu_estimate": mfu,
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             rules: sharding.AxisRules | None = None, save: bool = True,
             extrapolate: bool | None = None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_is_applicable(cfg, shape)
    if extrapolate is None:
        # multi-pod pass proves compile + sharding; roofline is single-pod
        extrapolate = not multi_pod
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "skipped": why}
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
        lowered = lower_cell(cfg, shape, mesh, rules)
        rec = analyze(lowered, cfg, shape, mesh, rules, extrapolate=extrapolate)
    if save:
        tag = "multipod" if multi_pod else "singlepod"
        d = os.path.abspath(os.path.join(ARTIFACT_DIR, tag))
        os.makedirs(d, exist_ok=True)
        with open(os.path.join(d, f"{arch}__{shape_name}.json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--strict", action="store_true", help="stop on first failure")
    args = ap.parse_args()

    cells = []
    if args.all:
        # cheap cells first so partial sweeps still cover most of the table
        arch_order = ["smollm-360m", "phi3-medium-14b", "stablelm-12b",
                      "qwen3-14b", "moonshot-v1-16b-a3b", "seamless-m4t-large-v2",
                      "recurrentgemma-9b", "llama4-maverick-400b-a17b",
                      "qwen2-vl-72b", "xlstm-1.3b"]
        shape_order = ["decode_32k", "long_500k", "train_4k", "prefill_32k"]
        for a in arch_order:
            for s in shape_order:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape)]

    for arch, shape_name in cells:
        t0 = time.time()
        try:
            rec = run_cell(arch, shape_name, multi_pod=args.multi_pod)
        except Exception as e:  # a dry-run failure is a bug; surface loudly
            msg = str(e).splitlines()[0][:200] if str(e) else ""
            print(f"FAIL  {arch} x {shape_name}: {type(e).__name__}: {msg}", flush=True)
            if args.strict:
                raise
            continue
        if "skipped" in rec:
            print(f"SKIP  {arch} x {shape_name}: {rec['skipped']}")
            continue
        r = rec["roofline"]
        print(f"OK    {arch} x {shape_name} [{rec['mesh']}] "
              f"compile {rec['compile_s']}s | "
              f"mem/dev {rec['memory']['total_gib_per_dev']} GiB fits={rec['memory']['fits_16g']} | "
              f"compute {r['compute_s']:.3e}s mem {r['memory_s']:.3e}s coll {r['collective_s']:.3e}s "
              f"bound={r['bound']} | useful {rec['useful_flops_ratio']:.2f} "
              f"MFU~{rec['mfu_estimate']:.2%} ({time.time()-t0:.0f}s)")


if __name__ == "__main__":
    main()
