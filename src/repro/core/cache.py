"""Bounded caches for long-lived engine/service processes.

A one-shot `codesign()` run can afford unbounded memoization, but the
co-design service (`repro.service`) keeps engines and spaces alive across
many requests, so every cache in the hot path is bounded here and counts its
traffic:

  `LRUCache`    the `CodesignEngine` (hw, layer) -> (mapping, EDP) cache: a
                dict-compatible mapping with optional LRU eviction
                (`maxsize=0` keeps the historical unbounded behavior) and
                hit/miss/eviction counters that `CoDesignResult.stats`
                surfaces per run.
  `SlotCache`   the identity-keyed packed-array memos of
                `HardwareSpace.features_batch` / `SoftwareSpace`'s forward
                and feature caches: a tiny LRU over `is`-compared pool
                objects (the historical one-slot tuples, generalized and
                counted).  Traffic tallies into the module-level `COUNTERS`
                so per-probe spaces -- created and dropped inside one outer
                trial -- still aggregate into the run's stats.

Eviction never changes search results when `prune="off"`: cache keys are
content-addressed and inner-search seeds are content-derived
(`CodesignEngine.probe_seed`), so a re-search after eviction reproduces the
evicted entry bit-for-bit.  With the bound gate on (`prune != "off"`), the
gate consults cache membership ("search already paid for"), so a bound tight
enough to evict live entries can change *when* probes are censored -- the
engine's default therefore stays unbounded and the service applies its bound
only where it owns the semantics.
"""

from __future__ import annotations

import collections
from typing import Any, Iterator, MutableMapping

# Global hit/miss tallies for the short-lived SlotCaches, keyed
# "<name>_hits" / "<name>_misses".  Snapshot + diff around a run to get
# per-run numbers (see `counters_snapshot`).
COUNTERS: collections.Counter = collections.Counter()


def counters_snapshot() -> dict[str, int]:
    """Copy of the global SlotCache tallies (diff two snapshots for a
    per-run reading)."""
    return dict(COUNTERS)


# `LRUCache._primed` sentinel: "no membership probe pending".  A distinct
# object (not None) so priming is unambiguous even for None keys.
_NO_KEY = object()


class LRUCache(MutableMapping):
    """Dict-compatible mapping with optional LRU eviction and traffic
    counters.  `maxsize=0` (default) disables eviction -- the mapping then
    behaves exactly like the plain dict it replaces, counters aside.

    Lookups (`[]`, `.get`, `in`) refresh recency and tally `hits`/`misses`;
    insertion beyond `maxsize` evicts the least-recently-used entry and
    tallies `evictions`.

    One logical lookup counts once: the engine's idiomatic
    `if key in cache: use(cache[key])` probe is a single lookup, so the
    membership test *primes* the key and the immediately following `[]` read
    of that same key skips its tally (any other operation in between clears
    the prime).  Without this, `__contains__` and `__getitem__` each tallied
    and the `cache_*` stats in `CoDesignResult` double-counted every
    in-then-read access."""

    def __init__(self, maxsize: int = 0):
        if maxsize < 0:
            raise ValueError(f"maxsize must be >= 0, got {maxsize!r}")
        self.maxsize = int(maxsize)
        self._data: collections.OrderedDict = collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self._primed: Any = _NO_KEY

    def __getitem__(self, key) -> Any:
        primed, self._primed = self._primed, _NO_KEY
        counted = primed is _NO_KEY or primed != key
        try:
            value = self._data[key]
        except KeyError:
            if counted:
                self.misses += 1
            raise
        self._data.move_to_end(key)
        if counted:
            self.hits += 1
        return value

    def __contains__(self, key) -> bool:
        self._primed = key
        if key in self._data:
            self._data.move_to_end(key)
            self.hits += 1
            return True
        self.misses += 1
        return False

    def __setitem__(self, key, value) -> None:
        self._primed = _NO_KEY
        self._data[key] = value
        self._data.move_to_end(key)
        if self.maxsize and len(self._data) > self.maxsize:
            self._data.popitem(last=False)
            self.evictions += 1

    def __delitem__(self, key) -> None:
        self._primed = _NO_KEY
        del self._data[key]

    def __iter__(self) -> Iterator:
        return iter(self._data)

    def items(self):
        """Uncounted point-in-time (key, value) list, LRU order.  The default
        `MutableMapping.items()` view reads through `__getitem__`, whose
        recency refresh would mutate the dict mid-iteration (and skew the
        traffic counters); snapshots use this instead."""
        return [(k, self._data[k]) for k in list(self._data)]

    def __len__(self) -> int:
        return len(self._data)

    def __repr__(self) -> str:
        return (f"LRUCache(maxsize={self.maxsize}, len={len(self._data)}, "
                f"hits={self.hits}, misses={self.misses}, "
                f"evictions={self.evictions})")


class SlotCache:
    """Tiny identity-keyed LRU for per-pool derived arrays (the generalized
    one-slot `(pool, value)` memo).  Keys compare by `is`: a pool object
    re-presented across frozen-window trials or back-to-back protocol calls
    hits; equal-valued but distinct pools do not (identity is the memo's
    correctness contract -- pools are never mutated in place).

    `name` routes hit/miss tallies into the module `COUNTERS`
    ("<name>_hits" / "<name>_misses") so short-lived space instances still
    aggregate into run-level stats.
    """

    def __init__(self, name: str, capacity: int = 2):
        assert capacity >= 1
        self.name = name
        self.capacity = capacity
        self._slots: list[tuple[object, Any]] = []

    def get(self, key) -> Any | None:
        for i, (k, v) in enumerate(self._slots):
            if k is key:
                if i != len(self._slots) - 1:
                    self._slots.append(self._slots.pop(i))
                COUNTERS[self.name + "_hits"] += 1
                return v
        COUNTERS[self.name + "_misses"] += 1
        return None

    def put(self, key, value) -> None:
        # Replace in place on a re-put of an already-present key (and refresh
        # its recency): appending a duplicate slot would make `get` serve the
        # stale older slot and could push a *distinct* live entry out of the
        # memo.
        for i, (k, _) in enumerate(self._slots):
            if k is key:
                self._slots[i] = (key, value)
                if i != len(self._slots) - 1:
                    self._slots.append(self._slots.pop(i))
                return
        self._slots.append((key, value))
        if len(self._slots) > self.capacity:
            self._slots.pop(0)
