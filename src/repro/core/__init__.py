"""The paper's contribution: nested constrained Bayesian optimization for
hardware/software co-design, plus the beyond-paper TPU sharding autotuner."""

from repro.core.gp import GP, GPClassifier
from repro.core.acquisition import expected_improvement, lcb, make_acquisition
from repro.core.bo import BOResult, bo_maximize
from repro.core.swspace import SoftwareSpace
from repro.core.hwspace import HardwareSpace
from repro.core.nested import CoDesignResult, codesign, optimize_software
from repro.core.baselines import random_search, relax_round_bo, tvm_style_search
from repro.core.trees import GradientBoostedTrees, RandomForestSurrogate

__all__ = [
    "GP",
    "GPClassifier",
    "expected_improvement",
    "lcb",
    "make_acquisition",
    "BOResult",
    "bo_maximize",
    "SoftwareSpace",
    "HardwareSpace",
    "CoDesignResult",
    "codesign",
    "optimize_software",
    "random_search",
    "relax_round_bo",
    "tvm_style_search",
    "GradientBoostedTrees",
    "RandomForestSurrogate",
]
