"""The paper's contribution: nested constrained Bayesian optimization for
hardware/software co-design, plus the beyond-paper TPU sharding autotuner."""

from repro.core.gp import GP, GPClassifier, GPClassifierStack, GPStack
from repro.core.acquisition import expected_improvement, lcb, make_acquisition
from repro.core.bo import BOResult, bo_maximize, bo_maximize_many
from repro.core.swspace import LayerStackSpace, SoftwareSpace
from repro.core.hwspace import HardwareSpace
from repro.core.nested import (CoDesignResult, codesign, optimize_software,
                               optimize_software_many)
from repro.core.baselines import random_search, relax_round_bo, tvm_style_search
from repro.core.trees import GradientBoostedTrees, RandomForestSurrogate

__all__ = [
    "GP",
    "GPClassifier",
    "GPClassifierStack",
    "GPStack",
    "expected_improvement",
    "lcb",
    "make_acquisition",
    "BOResult",
    "bo_maximize",
    "bo_maximize_many",
    "LayerStackSpace",
    "SoftwareSpace",
    "HardwareSpace",
    "CoDesignResult",
    "codesign",
    "optimize_software",
    "optimize_software_many",
    "random_search",
    "relax_round_bo",
    "tvm_style_search",
    "GradientBoostedTrees",
    "RandomForestSurrogate",
]
