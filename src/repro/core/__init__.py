"""The paper's contribution: nested constrained Bayesian optimization for
hardware/software co-design, plus the beyond-paper TPU sharding autotuner.

The search surface is the typed config API (`repro.core.config`):
`CodesignConfig` (sw/hw/engine sections, JSON round-trip) run by a
`CodesignEngine`; `codesign(**legacy_kwargs)` remains as a deprecation shim.
"""

from repro.core.config import (ACQUISITIONS, BACKENDS, EXECUTOR_KINDS,
                               PALLAS_MODES, PRUNE_MODES, STRATEGIES,
                               SURROGATES, CodesignConfig, EngineConfig,
                               ExecutorConfig, HWSearchConfig, SearchConfig,
                               ServiceConfig, SWSearchConfig,
                               config_from_legacy_kwargs)
from repro.core.cache import LRUCache, SlotCache, counters_snapshot
from repro.core.gp import GP, GPClassifier, GPClassifierStack, GPStack
from repro.core.acquisition import expected_improvement, lcb, make_acquisition
from repro.core.bo import (BOLoop, BOResult, FanoutSearchSpec, bo_maximize,
                           bo_maximize_many, score_topk)
from repro.core.swspace import LayerStackSpace, SoftwareSpace, fanout_spaces
from repro.core.hwspace import HardwareSpace
from repro.core.nested import (PROBE_STRATEGIES, CoDesignResult,
                               CodesignEngine, LayerBatchedProbes,
                               ProbeFanoutProbes, ProbeStrategy,
                               SearchSession, SequentialProbes,
                               SpeculativeProbes, codesign, optimize_software,
                               optimize_software_fanout,
                               optimize_software_many)
from repro.core.baselines import random_search, relax_round_bo, tvm_style_search
from repro.core.trees import GradientBoostedTrees, RandomForestSurrogate

__all__ = [
    "ACQUISITIONS",
    "BACKENDS",
    "EXECUTOR_KINDS",
    "PALLAS_MODES",
    "PRUNE_MODES",
    "STRATEGIES",
    "SURROGATES",
    "CodesignConfig",
    "EngineConfig",
    "ExecutorConfig",
    "HWSearchConfig",
    "SearchConfig",
    "ServiceConfig",
    "SWSearchConfig",
    "config_from_legacy_kwargs",
    "LRUCache",
    "SlotCache",
    "counters_snapshot",
    "GP",
    "GPClassifier",
    "GPClassifierStack",
    "GPStack",
    "expected_improvement",
    "lcb",
    "make_acquisition",
    "BOLoop",
    "BOResult",
    "FanoutSearchSpec",
    "bo_maximize",
    "bo_maximize_many",
    "score_topk",
    "LayerStackSpace",
    "SoftwareSpace",
    "fanout_spaces",
    "HardwareSpace",
    "PROBE_STRATEGIES",
    "CoDesignResult",
    "CodesignEngine",
    "SearchSession",
    "LayerBatchedProbes",
    "ProbeFanoutProbes",
    "ProbeStrategy",
    "SequentialProbes",
    "SpeculativeProbes",
    "codesign",
    "optimize_software",
    "optimize_software_fanout",
    "optimize_software_many",
    "random_search",
    "relax_round_bo",
    "tvm_style_search",
    "GradientBoostedTrees",
    "RandomForestSurrogate",
]
