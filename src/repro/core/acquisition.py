"""Acquisition functions (paper §3.3) in the *maximization* convention.

The optimizer maximizes utility = normalized reciprocal EDP (equivalently we fit
the GP on -log EDP).  LCB here follows the paper's formula a = mu + lambda*sigma
(an upper bound in maximize convention; the paper keeps the LCB name).
"""

from __future__ import annotations

import numpy as np


def _norm_pdf(z):
    return np.exp(-0.5 * z * z) / np.sqrt(2.0 * np.pi)


def _norm_cdf(z):
    # Standard normal CDF: Phi(z) = (1 + erf(z / sqrt(2))) / 2.  (The sqrt(2)
    # was historically missing, which made EI use an N(0, 1/2) CDF and
    # diverge from the device-resident twin below.)
    from scipy.special import erf

    z = np.asarray(z, dtype=np.float64)
    return 0.5 * (1.0 + erf(z / np.sqrt(2.0)))


def expected_improvement(mu: np.ndarray, var: np.ndarray, best: float) -> np.ndarray:
    sigma = np.sqrt(var)
    z = (mu - best) / np.maximum(sigma, 1e-12)
    return (mu - best) * _norm_cdf(z) + sigma * _norm_pdf(z)


def lcb(mu: np.ndarray, var: np.ndarray, lam: float = 1.0) -> np.ndarray:
    return mu + lam * np.sqrt(var)


def make_acquisition(name: str, lam: float = 1.0):
    if name == "ei":
        return lambda mu, var, best: expected_improvement(mu, var, best)
    if name == "lcb":
        return lambda mu, var, best: lcb(mu, var, lam)
    raise ValueError(name)


def make_acquisition_device(name: str, lam: float = 1.0):
    """`jnp` twins of the acquisitions, for the device-resident pool-scoring
    path (JAX evaluation engine + GP posterior, no host round-trip).  Each
    twin traces under scoped x64 -- without it, transcendental ops like erf
    canonicalize their internal constants to f32 and silently degrade the f64
    posterior's precision (the same class of bug as the old global-flag
    import side effect, just in the other direction)."""
    import math

    import jax.numpy as jnp
    from jax.experimental import enable_x64
    from jax.scipy.special import erf

    def ei(mu, var, best):
        with enable_x64():
            sigma = jnp.sqrt(var)
            z = (mu - best) / jnp.maximum(sigma, 1e-12)
            pdf = jnp.exp(-0.5 * z * z) / math.sqrt(2.0 * math.pi)
            cdf = 0.5 * (1.0 + erf(z / math.sqrt(2.0)))
            return (mu - best) * cdf + sigma * pdf

    def lcb(mu, var, best):
        with enable_x64():
            return mu + lam * jnp.sqrt(var)

    if name == "ei":
        return ei
    if name == "lcb":
        return lcb
    raise ValueError(name)
