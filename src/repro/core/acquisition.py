"""Acquisition functions (paper §3.3) in the *maximization* convention.

The optimizer maximizes utility = normalized reciprocal EDP (equivalently we fit
the GP on -log EDP).  LCB here follows the paper's formula a = mu + lambda*sigma
(an upper bound in maximize convention; the paper keeps the LCB name).
"""

from __future__ import annotations

import numpy as np


def _norm_pdf(z):
    return np.exp(-0.5 * z * z) / np.sqrt(2.0 * np.pi)


def _norm_cdf(z):
    from math import erf

    z = np.asarray(z, dtype=np.float64)
    return 0.5 * (1.0 + np.vectorize(erf)(z))


def expected_improvement(mu: np.ndarray, var: np.ndarray, best: float) -> np.ndarray:
    sigma = np.sqrt(var)
    z = (mu - best) / np.maximum(sigma, 1e-12)
    return (mu - best) * _norm_cdf(z) + sigma * _norm_pdf(z)


def lcb(mu: np.ndarray, var: np.ndarray, lam: float = 1.0) -> np.ndarray:
    return mu + lam * np.sqrt(var)


def make_acquisition(name: str, lam: float = 1.0):
    if name == "ei":
        return lambda mu, var, best: expected_improvement(mu, var, best)
    if name == "lcb":
        return lambda mu, var, best: lcb(mu, var, lam)
    raise ValueError(name)
