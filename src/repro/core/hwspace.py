"""Hardware search space (paper §4.2).

Known constraints (mesh products, storage budget) are input constraints enforced
at sampling time; the *unknown* constraint -- "does a feasible software mapping
exist / can the inner optimizer find one" -- surfaces through evaluate() and is
modeled by the SE-kernel GP classifier in the BO loop.  Hardware evaluation is
noisy (the inner SW search is stochastic), so the objective GP keeps a learned
noise kernel.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.timeloop.arch import HardwareConfig, hw_is_valid, sample_hardware

HW_FEATURE_NAMES = (
    "mesh_x_ratio",       # PE mesh-X / GB mesh-X  (Fig. 13)
    "mesh_y_ratio",       # PE mesh-Y / GB mesh-Y  (Fig. 13)
    "log_pe_mesh_x",
    "log_pe_mesh_y",
    "lb_input_frac",
    "lb_weight_frac",
    "lb_output_frac",
    "log_gb_instances",
    "log_gb_bandwidth",
    "df_fw",
    "df_fh",
)


@dataclasses.dataclass
class HardwareSpace:
    num_pes: int = 168
    base: HardwareConfig | None = None
    # evaluate_fn(hw) -> (utility | None, feasible); injected by the nested driver.
    evaluate_fn: Callable[[HardwareConfig], tuple[float | None, bool]] | None = None
    name: str = "hardware"
    # Evaluating one hardware point is a full inner software search, so there is
    # nothing to vectorize at this level: the BO loop takes its scalar path.
    supports_batch: bool = False

    @property
    def feature_dim(self) -> int:
        return len(HW_FEATURE_NAMES)

    def sample(self, rng) -> HardwareConfig:
        while True:
            hw = sample_hardware(rng, num_pes=self.num_pes, base=self.base)
            if hw_is_valid(hw)[0]:
                return hw

    def is_valid(self, hw: HardwareConfig) -> bool:
        return hw_is_valid(hw)[0]

    def features(self, hw: HardwareConfig) -> np.ndarray:
        return np.array(
            [
                hw.pe_mesh_x / hw.gb_mesh_x,
                hw.pe_mesh_y / hw.gb_mesh_y,
                np.log1p(hw.pe_mesh_x),
                np.log1p(hw.pe_mesh_y),
                hw.lb_input / hw.lb_budget,
                hw.lb_weight / hw.lb_budget,
                hw.lb_output / hw.lb_budget,
                np.log1p(hw.gb_instances),
                np.log1p(hw.gb_bandwidth),
                float(hw.df_fw - 1),
                float(hw.df_fh - 1),
            ],
            dtype=np.float64,
        )

    def evaluate(self, hw: HardwareConfig) -> tuple[float | None, bool]:
        assert self.evaluate_fn is not None, "inject evaluate_fn (nested driver)"
        return self.evaluate_fn(hw)
