"""Hardware search space (paper §4.2).

Known constraints (mesh products, storage budget) are input constraints enforced
at sampling time; the *unknown* constraint -- "does a feasible software mapping
exist / can the inner optimizer find one" -- surfaces through evaluate() and is
modeled by the SE-kernel GP classifier in the BO loop.  Hardware evaluation is
noisy (the inner SW search is stochastic), so the objective GP keeps a learned
noise kernel.

The space implements the BO loop's *batched evaluation protocol*
(`supports_batch` / `sample_pool` / `features_batch` / `evaluate_batch`): the
150-candidate acquisition pools are drawn by the array-vectorized sampler
(`arch.sample_hardware_pool`) and featurized as one packed (n, 11) matrix
instead of one config at a time.  Evaluation stays scalar underneath --
scoring one hardware point *is* a full inner software search, so
`evaluate_batch` (used only for the handful of warmup points) simply loops;
the batching win is in pool construction and featurization, which run once
per outer BO trial.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.cache import SlotCache
from repro.timeloop.arch import (HardwareConfig, hw_is_valid, sample_hardware,
                                 sample_hardware_pool)

HW_FEATURE_NAMES = (
    "mesh_x_ratio",       # PE mesh-X / GB mesh-X  (Fig. 13)
    "mesh_y_ratio",       # PE mesh-Y / GB mesh-Y  (Fig. 13)
    "log_pe_mesh_x",
    "log_pe_mesh_y",
    "lb_input_frac",
    "lb_weight_frac",
    "lb_output_frac",
    "log_gb_instances",
    "log_gb_bandwidth",
    "df_fw",
    "df_fh",
)


@dataclasses.dataclass
class HardwareSpace:
    num_pes: int = 168
    base: HardwareConfig | None = None
    # evaluate_fn(hw) -> (utility | None, feasible); injected by the nested driver.
    evaluate_fn: Callable[[HardwareConfig], tuple[float | None, bool]] | None = None
    # prefetch_fn(pool): optional batch hook, called once with the whole pool
    # before evaluate_batch's scalar loop.  The nested driver's probe-fanout
    # strategy injects it to run ALL warmup probes' inner software searches as
    # one stacked multi-run fan-out; the loop below then reads cache hits.
    prefetch_fn: Callable[[list[HardwareConfig]], None] | None = None
    # prefetch_topk_fn(cands): optional per-scored-trial hook -- the BO loop
    # hands it the pool's top-`prefetch_topk` candidates ranked by acquisition
    # utility (best first; entry 0 is the trial's own argmax) before the argmax
    # is evaluated.  The nested driver's speculative strategy injects it to fan
    # the k probes' inner searches out as ONE stacked multi-run program: the
    # argmax probe's layers become cache hits for this trial's evaluation, the
    # k-1 speculative probes' for whichever later trial selects them.
    prefetch_topk_fn: Callable[[list[HardwareConfig]], None] | None = None
    prefetch_topk: int = 0
    # prune_fn(pool) -> pool: optional bound-and-prune hook applied to every
    # sampled candidate pool (warmup and scored trials alike).  The nested
    # driver injects it when `HWSearchConfig.prune != "off"`: candidates whose
    # summed per-layer EDP lower bound (`timeloop.bounds`) already exceeds the
    # incumbent's true model EDP are dropped before featurization, so the
    # acquisition -- and the speculative prefetch riding on it -- only ever
    # spends inner searches on candidates that can still win.  Must return a
    # non-empty subset (the driver's guard keeps the lowest-bound candidate).
    prune_fn: Callable[[list[HardwareConfig]], list[HardwareConfig]] | None = None
    # Opt in to the BO loop's frozen refit windows (gp_refit_every > 1 reuses
    # one pool per refit window with consumed candidates masked -- batched
    # q-batch acquisition).  An outer-loop semantic: spaces without this stay
    # on per-trial resampling, and the lockstep multi-run engine (which the
    # hardware loop never uses) keeps its sequential-parity contract.
    supports_pool_freeze: bool = True
    name: str = "hardware"
    # Pool sampling + featurization take the packed-array protocol; evaluation
    # itself is the nested inner search and stays scalar (see module
    # docstring).  Set False to force the scalar reference path.
    supports_batch: bool = True

    def __post_init__(self) -> None:
        # Pool-identity memo (the `SoftwareSpace._fwd_cache` idiom): a frozen
        # refit window re-presents the SAME pool object across its trials,
        # and the prune pass featurizes pools the BO loop featurizes again --
        # deriving the packed (n, 11) matrix once per pool object makes every
        # repeat free.  A bounded, counted SlotCache (capacity 2: the frozen
        # window's pool plus the freshest draw) so long-lived service
        # processes never accumulate stale pool arrays.
        self._feat_cache = SlotCache("hw_feat", capacity=2)

    @property
    def feature_dim(self) -> int:
        return len(HW_FEATURE_NAMES)

    def sample(self, rng) -> HardwareConfig:
        while True:
            hw = sample_hardware(rng, num_pes=self.num_pes, base=self.base)
            if hw_is_valid(hw)[0]:
                return hw

    def is_valid(self, hw: HardwareConfig) -> bool:
        return hw_is_valid(hw)[0]

    def features(self, hw: HardwareConfig) -> np.ndarray:
        return np.array(
            [
                hw.pe_mesh_x / hw.gb_mesh_x,
                hw.pe_mesh_y / hw.gb_mesh_y,
                np.log1p(hw.pe_mesh_x),
                np.log1p(hw.pe_mesh_y),
                hw.lb_input / hw.lb_budget,
                hw.lb_weight / hw.lb_budget,
                hw.lb_output / hw.lb_budget,
                np.log1p(hw.gb_instances),
                np.log1p(hw.gb_bandwidth),
                float(hw.df_fw - 1),
                float(hw.df_fh - 1),
            ],
            dtype=np.float64,
        )

    def evaluate(self, hw: HardwareConfig) -> tuple[float | None, bool]:
        assert self.evaluate_fn is not None, "inject evaluate_fn (nested driver)"
        return self.evaluate_fn(hw)

    # --- batched evaluation protocol --------------------------------------------

    def sample_pool(self, rng, n: int) -> list[HardwareConfig]:
        """n input-valid configs, array-vectorized draws (every draw satisfies
        the structural constraints by construction, so no rejection rounds).
        An injected `prune_fn` filters the draw afterwards -- it consumes no
        RNG, so runs with pruning off and on share the identical sample
        stream."""
        pool = sample_hardware_pool(rng, n, num_pes=self.num_pes, base=self.base)
        if self.prune_fn is not None:
            pool = self.prune_fn(pool)
        return pool

    def features_batch(self, pool) -> np.ndarray:
        """(n, 11) feature matrix computed as whole-array column ops, memoized
        per pool identity (see `__post_init__`)."""
        cached = self._feat_cache.get(pool)
        if cached is not None:
            return cached
        cols = np.array(
            [
                [hw.pe_mesh_x, hw.pe_mesh_y, hw.gb_mesh_x, hw.gb_mesh_y,
                 hw.lb_input, hw.lb_weight, hw.lb_output, hw.lb_budget,
                 hw.gb_instances, hw.gb_bandwidth, hw.df_fw, hw.df_fh]
                for hw in pool
            ],
            dtype=np.float64,
        ).T
        (mx, my, gx, gy, li, lw, lo, budget, gbi, gbbw, fw, fh) = cols
        feats = np.stack(
            [
                mx / gx,
                my / gy,
                np.log1p(mx),
                np.log1p(my),
                li / budget,
                lw / budget,
                lo / budget,
                np.log1p(gbi),
                np.log1p(gbbw),
                fw - 1.0,
                fh - 1.0,
            ],
            axis=1,
        )
        self._feat_cache.put(pool, feats)
        return feats

    def evaluate_batch(self, pool) -> tuple[np.ndarray, np.ndarray]:
        """Scalar evaluation per config (each is a full inner software search;
        only the BO warmup calls this, on a handful of points).  When a
        `prefetch_fn` is injected, the whole pool is handed to it first --
        the probe-fanout strategy fans the pool's inner searches out as one
        stacked multi-run program, and the loop below hits its cache."""
        if self.prefetch_fn is not None:
            self.prefetch_fn(list(pool))
        vals = np.full(len(pool), -np.inf)
        feas = np.zeros(len(pool), dtype=bool)
        for i, hw in enumerate(pool):
            v, ok = self.evaluate(hw)
            feas[i] = ok
            if ok:
                vals[i] = v
        return vals, feas
