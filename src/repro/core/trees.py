"""Tree ensembles in pure numpy.

RandomForestSurrogate  -- the RF surrogate from the paper's ablation (Fig. 5b):
                          mean/variance across trees drive the acquisition.
GradientBoostedTrees   -- the learned cost model for the TVM-style baseline
                          (Chen et al. 2018 use XGBoost; we implement equivalent
                          least-squares gradient boosting on shallow CARTs).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class _Node:
    feature: int = -1
    threshold: float = 0.0
    left: "_Node | None" = None
    right: "_Node | None" = None
    value: float = 0.0


def _build_tree(X, y, rng, max_depth, min_leaf, n_feat_try) -> _Node:
    node = _Node(value=float(y.mean()))
    if max_depth == 0 or len(y) < 2 * min_leaf or np.allclose(y, y[0]):
        return node
    n, d = X.shape
    feats = rng.choice(d, size=min(n_feat_try, d), replace=False)
    best = (0.0, -1, 0.0)  # (gain, feature, threshold)
    base = ((y - y.mean()) ** 2).sum()
    for f in feats:
        xs = X[:, f]
        order = np.argsort(xs)
        ys = y[order]
        csum = np.cumsum(ys)
        csq = np.cumsum(ys**2)
        tot, totsq = csum[-1], csq[-1]
        for i in range(min_leaf, n - min_leaf):
            if xs[order[i]] == xs[order[i - 1]]:
                continue
            nl = i
            sse_l = csq[i - 1] - csum[i - 1] ** 2 / nl
            nr = n - i
            sse_r = (totsq - csq[i - 1]) - (tot - csum[i - 1]) ** 2 / nr
            gain = base - (sse_l + sse_r)
            if gain > best[0]:
                best = (gain, f, 0.5 * (xs[order[i]] + xs[order[i - 1]]))
    if best[1] < 0:
        return node
    _, f, thr = best
    mask = X[:, f] <= thr
    node.feature, node.threshold = int(f), float(thr)
    node.left = _build_tree(X[mask], y[mask], rng, max_depth - 1, min_leaf, n_feat_try)
    node.right = _build_tree(X[~mask], y[~mask], rng, max_depth - 1, min_leaf, n_feat_try)
    return node


def _predict_tree(node: _Node, X) -> np.ndarray:
    out = np.empty(len(X))
    for i, x in enumerate(X):
        n = node
        while n.left is not None:
            n = n.left if x[n.feature] <= n.threshold else n.right
        out[i] = n.value
    return out


@dataclasses.dataclass
class RandomForestSurrogate:
    n_trees: int = 30
    max_depth: int = 8
    min_leaf: int = 2
    seed: int = 0
    _trees: list | None = None

    def fit(self, X, y):
        X = np.asarray(X, np.float64)
        y = np.asarray(y, np.float64)
        rng = np.random.default_rng(self.seed)
        n, d = X.shape
        self._trees = []
        for _ in range(self.n_trees):
            idx = rng.integers(n, size=n)
            self._trees.append(
                _build_tree(X[idx], y[idx], rng, self.max_depth, self.min_leaf,
                            max(1, int(np.ceil(d / 3))))
            )
        return self

    def posterior(self, Xs):
        Xs = np.asarray(Xs, np.float64)
        preds = np.stack([_predict_tree(t, Xs) for t in self._trees])
        return preds.mean(0), np.maximum(preds.var(0), 1e-10)


@dataclasses.dataclass
class GradientBoostedTrees:
    n_rounds: int = 40
    max_depth: int = 4
    lr: float = 0.2
    seed: int = 0
    _trees: list | None = None
    _base: float = 0.0

    def fit(self, X, y):
        X = np.asarray(X, np.float64)
        y = np.asarray(y, np.float64)
        rng = np.random.default_rng(self.seed)
        self._base = float(y.mean())
        resid = y - self._base
        self._trees = []
        d = X.shape[1]
        for _ in range(self.n_rounds):
            t = _build_tree(X, resid, rng, self.max_depth, 2, d)
            resid = resid - self.lr * _predict_tree(t, X)
            self._trees.append(t)
        return self

    def predict(self, Xs):
        Xs = np.asarray(Xs, np.float64)
        out = np.full(len(Xs), self._base)
        for t in self._trees:
            out += self.lr * _predict_tree(t, Xs)
        return out
