"""Nested hardware/software co-design (paper §4.1, Fig. 1).

Outer loop: constrained BO over hardware configurations (50 trials in the paper).
Inner loop: for each candidate hardware, per-layer constrained BO over software
mappings (250 trials in the paper); layer-wise EDPs are summed into the model
EDP that the hardware optimizer sees.  The hardware objective is noisy (the
inner search is stochastic) -> noise kernel on; a hardware point with no
discoverable mapping for some layer is an *unknown-constraint* violation.

The search is configured by one typed, serializable `CodesignConfig`
(`repro.core.config`) and driven by a `CodesignEngine`, which owns the
(hw, layer) -> best-mapping cache, the inner-seed stream, and a pluggable
*probe-evaluation strategy* (`PROBE_STRATEGIES`):

  "sequential"     L per-layer `optimize_software` searches per hardware probe
  "layer_batched"  one lockstep `bo_maximize_many` call per probe: the L
                   per-layer searches advance together, one fused device
                   program + one stacked GP fit per BO round
  "probe_fanout"   layer_batched per probe, PLUS the outer loop's H warmup
                   probes -- independent work items -- fanned out as ONE
                   H*L-run stacked `bo_maximize_many` (each run seeded exactly
                   as its probe's sequential search would be, so results are
                   identical; on the JAX backend every BO round is a single
                   (H*L*B,)-row fused dispatch)
  "speculative"    probe_fanout, PLUS speculative fan-out of the scored outer
                   trials: each trial's top-`hw.spec_k` acquisition candidates
                   are evaluated as ONE k*L-run stacked `bo_maximize_many`
                   (the argmax feeds the outer history exactly as the
                   sequential path would; the k-1 speculative results prefill
                   the (hw, layer) cache so later trials that select them are
                   free -- hit-rate reported in `CoDesignResult.stats`)
  "auto"           layer_batched when the backend is "jax", else sequential

Probe seeds are *content-derived* (`CodesignEngine.probe_seed`: a stable hash
of the run seed and the probe's fields), so a probe's inner search is the same
no matter when -- or how speculatively -- it is evaluated; that is what makes
every strategy above bit-identical to "sequential" (within the stacked GP's
Cholesky regime, see tests/test_speculative.py).

`codesign(**legacy_kwargs)` remains as a thin deprecation shim with pinned
result parity (tests/test_config_api.py).
"""

from __future__ import annotations

import dataclasses
import hashlib
import warnings
from typing import Callable, Sequence

import numpy as np

from repro.core.bo import (BOLoop, BOResult, FanoutSearchSpec,
                           InfeasibleSpace, _resolve_search_config,
                           bo_maximize, bo_maximize_many, score_topk)
from repro.core.cache import LRUCache, counters_snapshot
from repro.core.config import (CodesignConfig, EngineConfig, SWSearchConfig,
                               config_from_legacy_kwargs)
from repro.core.hwspace import HardwareSpace
from repro.core.swspace import SoftwareSpace, fanout_spaces
from repro.timeloop.arch import HardwareConfig, hw_from_tuple
from repro.timeloop.mapping import Mapping
from repro.timeloop.model import evaluate
from repro.timeloop.workloads import ConvLayer


@dataclasses.dataclass
class CoDesignResult:
    best_hw: HardwareConfig
    best_mappings: dict[str, Mapping]
    best_model_edp: float            # sum over layers, pJ*cycles
    hw_result: BOResult
    layer_edps: dict[str, float]
    # Engine accounting for the run: speculative probes evaluated / consumed
    # as cache hits and the resulting hit rate (all zero for non-speculative
    # strategies), plus the bound-and-prune pass's candidates considered /
    # pruned and the resulting pruned fraction, and the scored probes whose
    # whole inner search was vetoed by the bound gate (`probes_gated`; all
    # zero with prune="off").
    stats: dict | None = None


_SEARCH_FIELDS = {f.name for f in dataclasses.fields(SWSearchConfig)}
_ENGINE_FIELDS = {f.name for f in dataclasses.fields(EngineConfig)}


def _split_config(config, engine, overrides):
    """Normalize (search config, engine config, legacy kwarg overrides) into
    one validated pair.  Overrides are the configs' own field names -- search
    fields (n_trials, pool_size, ...) land on the search config, engine fields
    (backend, batched, gp_refit_every, pallas_mode, ...) on the engine config;
    anything else raises TypeError."""
    search_kw = {k: overrides.pop(k) for k in list(overrides)
                 if k in _SEARCH_FIELDS}
    engine_kw = {k: overrides.pop(k) for k in list(overrides)
                 if k in _ENGINE_FIELDS}
    if overrides:
        raise TypeError(f"unexpected keyword argument(s) {sorted(overrides)}; "
                        f"valid: {sorted(_SEARCH_FIELDS | _ENGINE_FIELDS)}")
    cfg = _resolve_search_config(config, search_kw)  # shared type-check site
    if engine is not None and not isinstance(engine, EngineConfig):
        raise TypeError(f"engine must be an EngineConfig, got {engine!r}")
    eng = engine if engine is not None else EngineConfig()
    if engine_kw:
        eng = dataclasses.replace(eng, **engine_kw)
    return cfg, eng


def _software_space(hw: HardwareConfig, layer: ConvLayer,
                    eng: EngineConfig) -> SoftwareSpace:
    return SoftwareSpace(hw, layer, batched=eng.batched, backend=eng.backend,
                         pallas_mode=eng.pallas_mode)


def optimize_software(
    hw: HardwareConfig,
    layer: ConvLayer,
    config: SWSearchConfig | None = None,
    *,
    seed: int = 0,
    engine: EngineConfig | None = None,
    **overrides,
) -> BOResult:
    """One per-layer software-mapping search (paper §4.3).  Configured by a
    `SWSearchConfig` + `EngineConfig`; individual fields may be overridden by
    keyword (`optimize_software(hw, layer, n_trials=60, backend="jax")`)."""
    cfg, eng = _split_config(config, engine, overrides)
    space = _software_space(hw, layer, eng)
    try:
        return bo_maximize(
            space, cfg,
            noisy=False,  # deterministic evaluator (paper §4.3)
            seed=seed,
            gp_refit_every=eng.gp_refit_every,
        )
    except InfeasibleSpace:
        # No feasible mapping could even be sampled -> report an empty result;
        # the hardware level treats this as an unknown-constraint violation.
        return BOResult(None, -np.inf, [], [], [])


def optimize_software_many(
    hw: HardwareConfig,
    layers: Sequence[ConvLayer],
    config: SWSearchConfig | None = None,
    *,
    seed: int = 0,
    engine: EngineConfig | None = None,
    **overrides,
) -> list[BOResult]:
    """Layer-batched twin of `optimize_software`: the L per-layer searches of
    one hardware probe advance in lockstep through `bo_maximize_many` (each
    seeded exactly as the sequential per-layer calls would be), one fused
    evaluation program + one stacked surrogate fit per BO round.  A layer with
    no sampleable mapping yields an empty `BOResult` (best_point None), same
    as `optimize_software`'s InfeasibleSpace handling."""
    cfg, eng = _split_config(config, engine, overrides)
    spaces = [_software_space(hw, layer, eng) for layer in layers]
    return bo_maximize_many(
        spaces, cfg,
        noisy=False,  # deterministic evaluator (paper §4.3)
        seed=seed,
        gp_refit_every=eng.gp_refit_every,
    )


def optimize_software_fanout(
    items: Sequence[tuple[HardwareConfig, ConvLayer]],
    config: SWSearchConfig | None = None,
    *,
    seeds: Sequence[int],
    engine: EngineConfig | None = None,
    pad_to: int | None = None,
) -> list[BOResult]:
    """Probe-fanout twin of `optimize_software_many`: one stacked multi-run
    search over (hardware, layer) pairs that may span *different* hardware
    probes, each run seeded individually (`seeds[i]`, exactly as the
    sequential per-probe calls would be).  On the JAX backend every BO round
    of all H*L runs is a single (H*L*B,)-row fused device program -- the
    hardware vector rides per row, like the layer vector.

    `pad_to` pads the stack to a fixed run count with copies of run 0 on the
    JAX backend (see `swspace.fanout_spaces`): the speculative outer loop's
    per-trial item count varies as cached probes drop out, and a fixed width
    keeps one compiled per-round program across trials.  Only the first
    `len(items)` results are returned."""
    if len(items) != len(seeds):
        raise ValueError(f"{len(seeds)} seeds for {len(items)} items")
    cfg, eng = _split_config(config, engine, {})
    spaces = fanout_spaces(items, batched=eng.batched, backend=eng.backend,
                           pallas_mode=eng.pallas_mode, pad_to=pad_to)
    seeds = list(seeds)
    if len(spaces) > len(items):  # padded runs replay run 0's search
        seeds += [seeds[0]] * (len(spaces) - len(items))
    return bo_maximize_many(
        spaces, cfg,
        noisy=False,
        seed=seeds,
        gp_refit_every=eng.gp_refit_every,
    )[:len(items)]


# --- probe-evaluation strategies -------------------------------------------------


def _cache_entry(hw: HardwareConfig, layer: ConvLayer,
                 r: BOResult) -> tuple[Mapping | None, float]:
    if r.best_point is None:
        return (None, float("inf"))
    return (r.best_point, evaluate(hw, r.best_point, layer).edp)


class ProbeStrategy:
    """How a `CodesignEngine` evaluates one hardware probe's inner searches.

    `evaluate_probe` must fill `engine.cache` for the probe's layers (honoring
    `use_cache`); `prefetch` optionally batches the inner searches of a whole
    warmup pool ahead of the per-probe calls (the probe-fanout capability).
    Register implementations in `PROBE_STRATEGIES`."""

    name = "base"

    def evaluate_probe(self, engine: "CodesignEngine", hw: HardwareConfig,
                       seed: int) -> None:
        raise NotImplementedError

    def prefetch(self, engine: "CodesignEngine",
                 pool: Sequence[HardwareConfig]) -> None:
        """Called once with the outer warmup pool before its probes are
        evaluated; default: nothing (probes evaluate one at a time)."""

    def prefetch_topk(self, engine: "CodesignEngine",
                      cands: Sequence[HardwareConfig]) -> None:
        """Called per scored outer trial with the acquisition pool's top-k
        candidates, best first (entry 0 is the argmax the trial consumes);
        default: nothing (the speculative strategy overrides this)."""


class SequentialProbes(ProbeStrategy):
    """L sequential per-layer `optimize_software` searches per probe, stopping
    at the first layer with no feasible mapping (the pre-engine behavior)."""

    name = "sequential"

    def evaluate_probe(self, engine, hw, seed):
        cfg = engine.config
        for layer in engine._layers:
            key = (hw, layer)
            if not cfg.engine.use_cache or key not in engine.cache:
                r = optimize_software(hw, layer, cfg.sw, seed=seed,
                                      engine=cfg.engine)
                engine.cache[key] = _cache_entry(hw, layer, r)
            if engine.cache[key][0] is None:
                break  # unknown constraint: remaining layers never searched


class LayerBatchedProbes(ProbeStrategy):
    """One lockstep `bo_maximize_many` call per probe: every layer this probe
    still needs advances in one multi-run search (each layer seeded exactly as
    its sequential `optimize_software` call would be, so cached entries are
    interchangeable between strategies)."""

    name = "layer_batched"

    def evaluate_probe(self, engine, hw, seed):
        cfg = engine.config
        todo = list(dict.fromkeys(
            layer for layer in engine._layers
            if not cfg.engine.use_cache or (hw, layer) not in engine.cache))
        if not todo:
            return
        rs = optimize_software_many(hw, todo, cfg.sw, seed=seed,
                                    engine=cfg.engine)
        for layer, r in zip(todo, rs):
            engine.cache[(hw, layer)] = _cache_entry(hw, layer, r)


class ProbeFanoutProbes(LayerBatchedProbes):
    """Layer-batched per-probe evaluation PLUS warmup fan-out: the outer
    loop's H warmup probes are independent, so their H*L inner searches run as
    ONE stacked `bo_maximize_many` (content-derived per-run seeds --
    `CodesignEngine.probe_seed` -- make each run exactly the search eval_hw
    would launch for its probe; duplicate probes are searched once, exactly as
    the cache would serve them sequentially).  Requires `use_cache=True`
    (validated at `EngineConfig` construction)."""

    name = "probe_fanout"

    def prefetch(self, engine, pool):
        items, seeds, _ = engine.pending_items(pool)
        if not items:
            return
        for (hw, layer), entry in zip(items, engine.fanout(items, seeds)):
            engine.cache[(hw, layer)] = entry


class SpeculativeProbes(ProbeFanoutProbes):
    """Warmup fan-out (inherited) PLUS speculative scored trials: the outer BO
    loop hands `prefetch_topk` each trial pool's top-`hw.spec_k` acquisition
    candidates (best first), and ALL their pending (hw, layer) searches run as
    ONE stacked k*L-run `bo_maximize_many`.  Entry 0 is the argmax the trial
    itself consumes -- its searches are the trial's own work, just fanned;
    entries 1..k-1 are speculation whose results prefill the cache for
    whichever later trial selects them (hit-rate in `CodesignEngine.stats`).

    Because probe seeds are content-derived, a speculative fill is
    bit-identical to the search the sequential path would run whenever it
    first evaluates that probe, so speculation can never change what the
    outer loop finds -- only when the inner-search work happens (parity
    pinned in tests/test_speculative.py).  Requires `use_cache=True`
    (validated at `EngineConfig` construction)."""

    name = "speculative"

    def prefetch_topk(self, engine, cands):
        items, seeds, speculated = engine.pending_items(
            cands, mark_speculated=True)
        if not items:
            return
        n_layers = len(dict.fromkeys(engine._layers))
        entries = engine.fanout(
            items, seeds,
            # Bucketed fan-out width on jax: pad the stack to a whole number
            # of probes so the per-round fused program compiles for at most
            # spec_k distinct run counts as cached probes drop out of later
            # trials' top-k, while padding (real redundant runs -- lax.map GP
            # slices are NOT free on CPU) stays under one probe's worth.
            pad_to=-(-len(items) // n_layers) * n_layers)
        for (hw, layer), entry in zip(items, entries):
            engine.cache[(hw, layer)] = entry
        engine.stats["spec_evaluated"] += len(speculated)
        engine._speculated.update(speculated)

    def evaluate_probe(self, engine, hw, seed):
        if hw in engine._speculated:
            # First consumption of a speculative fill: the probe the outer
            # loop selected was evaluated ahead of time -> whole inner search
            # skipped (all its layers are cache hits below).
            engine._speculated.discard(hw)
            engine.stats["spec_hits"] += 1
        super().evaluate_probe(engine, hw, seed)


PROBE_STRATEGIES: dict[str, type[ProbeStrategy]] = {
    cls.name: cls
    for cls in (SequentialProbes, LayerBatchedProbes, ProbeFanoutProbes,
                SpeculativeProbes)
}


# --- the engine ------------------------------------------------------------------


class CodesignEngine:
    """Runs the nested co-design search for one `CodesignConfig`.

    Owns the pieces the old kwarg pipeline threaded implicitly:

      * the (hw, layer) -> (best mapping | None, EDP) cache.  The outer BO
        routinely re-probes hardware points (acquisition argmax over a sampled
        pool repeats configs, and pool candidates collide across trials); both
        keys are frozen dataclasses, so a hit skips the whole inner search.
        The inner search is stochastic, so caching also makes repeated probes
        of one hardware point consistent.  The cache is shared by all probe
        strategies (same keys, same values) and persists across `run` calls.
      * the probe-seed derivation: a probe's inner searches are seeded by
        `probe_seed(hw)` -- a stable content hash of (config.seed, the
        probe's fields) -- so the seed does not depend on WHEN the probe is
        evaluated.  That makes evaluation order a free variable: warmup
        fan-out, speculative prefetch, and the plain sequential walk all run
        the exact same search for any given probe.
      * the probe-evaluation strategy, resolved from
        `config.engine.strategy` against `PROBE_STRATEGIES`, and the
        speculative accounting (`stats`: probes evaluated speculatively,
        speculative cache hits; reset per `run`).
    """

    def __init__(self, config: CodesignConfig | None = None,
                 executor=None):
        self.config = config if config is not None else CodesignConfig()
        self.backend = self.config.engine.resolve_backend()
        self.strategy_name = self.config.engine.resolve_strategy()
        self.strategy = PROBE_STRATEGIES[self.strategy_name]()
        # LRU-bounded when `engine.cache_entries` > 0 (the service applies its
        # bound here); 0 keeps the historical unbounded dict behavior.
        self.cache: LRUCache = LRUCache(self.config.engine.cache_entries)
        self._layers: list[ConvLayer] = []
        self.stats: dict[str, int] = {"spec_evaluated": 0, "spec_hits": 0}
        self._speculated: set[HardwareConfig] = set()
        self._gate: Callable | None = None
        # Executor injection (the service shares one pool across slots); when
        # None, one is built lazily from `config.engine.executor` on the
        # first fan-out and owned (closed) by this engine.
        self._executor = executor
        self._owns_executor = False

    @property
    def executor(self):
        if self._executor is None:
            from repro.parallel.executor import make_executor

            self._executor = make_executor(self.config.engine.executor)
            self._owns_executor = True
        return self._executor

    def fanout(self, items, seeds, pad_to: int | None = None) -> list:
        """Run one stacked multi-item inner search through the executor and
        return its `(mapping | None, EDP)` cache entries in item order.
        Placement (inline / worker pool / chunking) is invisible here:
        content-derived seeds make the entries identical everywhere."""
        spec = FanoutSearchSpec(items=tuple(items), seeds=tuple(seeds),
                                sw=self.config.sw, engine=self.config.engine,
                                pad_to=pad_to)
        return self.executor.run(spec)

    def close(self) -> None:
        """Shut down an executor this engine created (no-op for injected
        executors and the never-used lazy default)."""
        if self._owns_executor and self._executor is not None:
            self._executor.close()
            self._executor = None
            self._owns_executor = False

    def probe_seed(self, hw: HardwareConfig) -> int:
        """Content-derived inner-search seed for one hardware probe: a stable
        (process- and platform-independent) hash of the run seed and the
        probe's field values.  Every strategy seeds a probe's inner searches
        through this, which is what lets speculative/fanned-out evaluation
        reproduce the sequential path bit-for-bit."""
        data = repr((self.config.seed, dataclasses.astuple(hw))).encode()
        return int.from_bytes(
            hashlib.blake2s(data, digest_size=8).digest(), "big")

    def _make_prune_fn(self, best: dict):
        """Bound-and-prune closure for `HardwareSpace.prune_fn` (the
        semi-decoupled pass, `timeloop.bounds`): drop pool candidates whose
        summed per-layer EDP lower bound exceeds the incumbent's true model
        EDP times `prune_margin`.  RNG-free, so the sample stream is
        untouched.

        Engaged only under `prune="aggressive"`: pool-level removal redirects
        every doomed selection into a *different* full inner search, which is
        wall-clock neutral at a fixed trial budget -- and it starves the
        bound gate (`_make_probe_gate`), whose censored cheap trials are
        where the measured "safe" speedup comes from.  Returns None
        otherwise."""
        cfg = self.config
        if cfg.hw.prune != "aggressive":
            return None
        margin = cfg.hw.prune_margin
        layt = None          # (layb, caps) packed lazily: run() owns _layers
        memo = [None, None]  # one-slot (pool identity, summed bounds) memo

        def bound_sums(pool) -> np.ndarray:
            nonlocal layt
            if memo[0] is pool:
                return memo[1]
            if self.backend == "jax":
                from repro.timeloop.batch_jax import edp_lower_bounds_device
                lbs = edp_lower_bounds_device(pool, self._layers)
            else:
                from repro.timeloop.batch import edp_lower_bounds_batch
                from repro.timeloop.bounds import (hw_bound_vecs, layer_caps,
                                                   layer_bound_vecs)
                if layt is None:
                    layt = (layer_bound_vecs(self._layers),
                            layer_caps(self._layers))
                lbs = edp_lower_bounds_batch(hw_bound_vecs(pool), *layt)
            memo[0], memo[1] = pool, lbs.sum(axis=1)
            return memo[1]

        def prune(pool):
            incumbent = best["edp"]
            if not pool or not np.isfinite(incumbent):
                return pool  # warmup: no incumbent yet, nothing to bound
            sums = bound_sums(pool)
            keep = sums <= incumbent * margin
            self.stats["prune_considered"] += len(pool)
            if keep.all():
                return pool
            if not keep.any():
                # Guard: never empty the pool -- keep the candidate with the
                # best (lowest) bound so the BO trial always has a point.
                keep[int(np.argmin(sums))] = True
            self.stats["prune_pruned"] += int(len(pool) - keep.sum())
            return [hw for hw, k in zip(pool, keep) if k]

        return prune

    def _make_probe_gate(self, best: dict):
        """Bound gate for scored probe evaluations: when the selected probe's
        summed per-layer lower bound already exceeds the incumbent's true
        model EDP (times `prune_margin` under "aggressive"), its whole inner
        mapping search is provably wasted -- the probe cannot win -- so the
        gate skips it and hands the outer loop a *censored* utility instead:
        `-log10(max(bound, incumbent))`, an upper bound on the probe's true
        utility that is clamped to never displace the incumbent as
        `best_value`.  The incumbent itself is only ever updated by true
        evaluations, so gating cannot corrupt the final answer -- it only
        swaps a doomed search for a certificate of doom.

        The savings come from acquisition mistakes: trials whose selected
        candidate an uninformed or stale posterior ranked on top even though
        the bound already rules it out (frozen refit windows consume a pool
        ranked against a posterior that is stale by up to `gp_refit_every`
        trials).  Each such trial collapses from a full k*L-trial inner
        search to one vectorized bound lookup, and the censored observation
        teaches the surrogate the region is dominated without searching it.
        Returns None when `hw.prune == "off"`."""
        cfg = self.config
        if cfg.hw.prune == "off":
            return None
        from repro.timeloop.bounds import lower_bound

        margin = 1.0 if cfg.hw.prune == "safe" else cfg.hw.prune_margin

        def gate(hw: HardwareConfig, count: bool = True) -> float | None:
            incumbent = best["edp"]
            if not np.isfinite(incumbent):
                return None  # warmup: no incumbent to bound against
            if all((hw, layer) in self.cache for layer in self._layers):
                return None  # search already paid for: use the true value
            s = sum(lower_bound(hw, layer) for layer in self._layers)
            if s <= incumbent * margin:
                return None
            if count:
                self.stats["probes_gated"] += 1
            return -float(np.log10(max(s, incumbent)))

        return gate

    def probe_doomed(self, hw: HardwareConfig) -> bool:
        """True when the bound gate would veto this probe's inner search --
        fan-out strategies use it to keep provably-wasted searches out of
        their stacked programs (the gate itself censors the probe if the
        outer loop ever consumes it)."""
        return self._gate is not None and self._gate(hw, count=False) is not None

    def pending_items(self, cands: Sequence[HardwareConfig], *,
                      mark_speculated: bool = False):
        """(hw, layer) work items still uncached for `cands` (deduplicated,
        pool order) with their content-derived seeds; `mark_speculated`
        additionally reports which non-argmax probes contributed items (the
        speculative-consumption accounting -- entry 0 of `cands` is the work
        its trial consumes itself).

        This is THE unit of schedulable inner-search work: the fan-out
        strategies stack a single trial's items into one multi-run program,
        and the co-design service (`repro.service`) stacks the items of many
        concurrent sessions' trials the same way -- content-derived seeds
        make both result-preserving."""
        items: list[tuple[HardwareConfig, ConvLayer]] = []
        seeds: list[int] = []
        speculated: list[HardwareConfig] = []
        seen: set[HardwareConfig] = set()
        for rank, hw in enumerate(cands):
            if hw in seen:
                continue  # later duplicate -> cache hit at evaluation time
            seen.add(hw)
            if self.probe_doomed(hw):
                continue  # bound veto: the gate censors it if ever consumed
            todo = [(hw, layer) for layer in dict.fromkeys(self._layers)
                    if (hw, layer) not in self.cache]
            if not todo:
                continue
            if mark_speculated and rank > 0:
                speculated.append(hw)
            items.extend(todo)
            seeds.extend([self.probe_seed(hw)] * len(todo))
        return items, seeds, speculated

    def session(self, layers: Sequence[ConvLayer],
                hw_callback: Callable[[int, "BOResult"], None] | None = None,
                *, prior: Sequence[dict] | None = None,
                trial_log: Callable[[dict], None] | None = None,
                ) -> "SearchSession":
        """Open a resumable `SearchSession` over `layers` (one at a time per
        engine: the session wires the engine's gate/stats/layer bookkeeping
        to itself).  `prior` seeds the outer GP with recorded trial-history
        rows and `trial_log` receives this session's finished outer trials
        (cross-run transfer; see `repro.service.store.TrialHistory`)."""
        return SearchSession(self, layers, hw_callback=hw_callback,
                             prior=prior, trial_log=trial_log)

    def run(self, layers: Sequence[ConvLayer],
            hw_callback: Callable[[int, "BOResult"], None] | None = None,
            ) -> CoDesignResult:
        """Run the nested search over `layers` to completion -- a
        `SearchSession` stepped straight through (`session()` exposes the
        stepwise form).  `hw_callback(t, bo_result)`, when given, fires after
        every outer hardware trial (the `BOLoop` callback) -- the prune
        benchmark uses it to timestamp the incumbent trajectory
        (time-to-quality measurements)."""
        session = self.session(layers, hw_callback=hw_callback)
        while session.step():
            pass
        return session.result()


class SearchSession:
    """One nested co-design search as an explicit, resumable state machine.

    Wraps the outer hardware `BOLoop` plus everything `CodesignEngine.run`
    used to hold in closures: the incumbent (`best`), the bound gate, the
    probe-strategy hooks, and the per-run stats.  The outer-trial state --
    GP history, frozen pool window, elite carry-forward, prune gate -- is
    stepped one trial at a time (`step`), snapshotted (`snapshot`/`restore`),
    and interleaved with other sessions by the co-design service.

    The scheduling surface is `pending()`: the (hw, layer) inner-search work
    items the *next* `step()` will need, with their content-derived seeds.
    An external scheduler may search them by any means (fused across many
    sessions, served from a persistent store) and pre-fill `engine.cache`;
    because seeds are content-derived, the session's trajectory is
    bit-identical whether the work was pre-filled or evaluated inline.

    One live session per engine: constructing a session rebinds the engine's
    `_layers`/`stats`/`_gate`/`_speculated` bookkeeping (the same reset
    `run()` historically performed per call).  The (hw, layer) cache is NOT
    reset -- it persists across sessions by design.
    """

    def __init__(self, engine: CodesignEngine, layers: Sequence[ConvLayer],
                 hw_callback: Callable[[int, "BOResult"], None] | None = None,
                 *, prior: Sequence[dict] | None = None,
                 trial_log: Callable[[dict], None] | None = None):
        self.engine = engine
        cfg = engine.config
        self._trial_log = trial_log
        engine._layers = list(layers)
        engine.stats = {"spec_evaluated": 0, "spec_hits": 0,
                        "prune_considered": 0, "prune_pruned": 0,
                        "probes_gated": 0}
        engine._speculated = set()
        self.best: dict = {"edp": np.inf, "hw": None, "maps": None,
                           "per_layer": None}
        self.gate = engine._gate = engine._make_probe_gate(self.best)
        self._spec_k = (cfg.hw.spec_k
                        if engine.strategy_name == "speculative" else 0)
        self.space = HardwareSpace(
            num_pes=cfg.hw.num_pes,
            evaluate_fn=self._eval_hw,
            prefetch_fn=lambda pool: engine.strategy.prefetch(engine, pool),
            prefetch_topk_fn=(
                (lambda cands: engine.strategy.prefetch_topk(engine, cands))
                if self._spec_k > 1 else None),
            prefetch_topk=self._spec_k,
            prune_fn=engine._make_prune_fn(self.best),
        )
        # Cross-run transfer: an EDP-lower-bound prior mean (opt-in) and the
        # replayed trial history, both feeding the outer loop's surrogate
        # before its first warmup probe.  With no prior and the bound mean
        # off, every argument below matches the historical construction
        # exactly (warm_start with an empty history is bit-identical to
        # cold).
        mean_fn = (self._make_bound_mean_fn()
                   if cfg.hw.warm_start_bound_mean else None)
        self.n_prior = len(prior) if prior else 0
        self.loop = BOLoop(
            self.space, cfg.hw,
            noisy=True,  # inner search stochasticity (paper §4.2)
            seed=cfg.seed,
            gp_refit_every=cfg.engine.hw_gp_refit_every,
            gp_rank1=cfg.engine.gp_rank1_updates,
            callback=hw_callback,
            prior=self._prior_from_rows(prior, mean_fn) if prior else None,
            prior_mean_fn=mean_fn,
        )
        self._cache_counts0 = (engine.cache.hits, engine.cache.misses,
                               engine.cache.evictions)
        self._feat_counts0 = counters_snapshot()

    def _make_bound_mean_fn(self):
        """Prior-mean closure for the outer GP (`hw.warm_start_bound_mean`):
        m(hw) = -log10(sum of per-layer EDP lower bounds), the
        ordering-accurate utility upper bound of `timeloop.bounds`, computed
        through the same batched bound paths as `_make_prune_fn` (identity
        memo included: the frozen-window pool re-presents across trials)."""
        engine = self.engine
        layt = None          # (layb, caps) packed lazily, as in _make_prune_fn
        memo = [None, None]  # one-slot (pool identity, m values) memo

        def mean_fn(pool) -> np.ndarray:
            nonlocal layt
            if memo[0] is pool:
                return memo[1]
            if engine.backend == "jax":
                from repro.timeloop.batch_jax import edp_lower_bounds_device
                lbs = np.asarray(edp_lower_bounds_device(pool, engine._layers))
            else:
                from repro.timeloop.batch import edp_lower_bounds_batch
                from repro.timeloop.bounds import (hw_bound_vecs, layer_caps,
                                                   layer_bound_vecs)
                if layt is None:
                    layt = (layer_bound_vecs(engine._layers),
                            layer_caps(engine._layers))
                lbs = edp_lower_bounds_batch(hw_bound_vecs(pool), *layt)
            memo[0] = pool
            memo[1] = -np.log10(np.asarray(lbs, dtype=np.float64).sum(axis=1))
            return memo[1]

        return mean_fn

    def _prior_from_rows(self, rows: Sequence[dict], mean_fn) -> dict:
        """Convert trial-history rows (`TrialHistory.load`) into the
        `BOLoop` prior dict: every row enters the classifier data, feasible
        rows additionally enter the objective GP's (and, when the bound mean
        is on, their m values are recomputed from the recorded hardware
        through the same `mean_fn` live trials use)."""
        X_feas: list[np.ndarray] = []
        y_feas: list[float] = []
        hw_feas: list[HardwareConfig] = []
        X_all: list[np.ndarray] = []
        feas_all: list[bool] = []
        for row in rows:
            feats = np.asarray(row["features"], dtype=np.float64)
            feasible = bool(row["feasible"])
            X_all.append(feats)
            feas_all.append(feasible)
            if feasible:
                if row["utility"] is None:
                    raise ValueError(
                        "feasible trial-history row carries no utility "
                        f"(corrupt or hand-edited log): {row!r}")
                X_feas.append(feats)
                y_feas.append(float(row["utility"]))
                if mean_fn is not None:
                    hw_feas.append(hw_from_tuple(row["hw"]))
        prior = {"X_feas": X_feas, "y_feas": y_feas,
                 "X_all": X_all, "feas_all": feas_all}
        if mean_fn is not None:
            prior["m_feas"] = ([float(v) for v in np.asarray(mean_fn(hw_feas))]
                               if hw_feas else [])
        return prior

    def _log_trial(self, hw: HardwareConfig, utility: float | None,
                   feasible: bool) -> None:
        """Record one finished TRUE outer evaluation into the trial log
        (bound-gate-censored probes never reach here: their utilities are
        bound certificates, not measurements)."""
        if self._trial_log is None:
            return
        self._trial_log({
            "hw": list(dataclasses.astuple(hw)),
            "features": [float(v) for v in self.space.features(hw)],
            "utility": None if utility is None else float(utility),
            "feasible": bool(feasible),
        })

    def _eval_hw(self, hw: HardwareConfig):
        engine, best, cfg = self.engine, self.best, self.engine.config
        if self.gate is not None:
            censored = self.gate(hw)
            if censored is not None:
                return censored, True  # bound veto: no inner search run
        engine.strategy.evaluate_probe(engine, hw, engine.probe_seed(hw))
        total_edp = 0.0
        maps: dict[str, Mapping] = {}
        per_layer: dict[str, float] = {}
        for layer in engine._layers:
            m, edp = engine.cache.get((hw, layer), (None, float("inf")))
            if m is None:
                self._log_trial(hw, None, False)
                return None, False  # unknown constraint: no feasible mapping
            total_edp += edp
            maps[layer.name] = m
            per_layer[layer.name] = edp
        if total_edp < best["edp"]:
            best.update(edp=total_edp, hw=hw, maps=maps, per_layer=per_layer)
        if cfg.verbose:
            print(f"  hw {hw.pe_mesh_x}x{hw.pe_mesh_y} "
                  f"lb=({hw.lb_input},{hw.lb_weight},{hw.lb_output}) "
                  f"-> model EDP {total_edp:.3e}")
        utility = -float(np.log10(total_edp))
        self._log_trial(hw, utility, True)
        return utility, True

    @property
    def done(self) -> bool:
        return self.loop.done

    def step(self) -> bool:
        """Advance one outer stage (the warmup block, then one hardware trial
        per call); returns True while the session has more work."""
        return self.loop.step()

    def pending(self):
        """(items, seeds): the uncached (hw, layer) inner searches the next
        `step()` will evaluate, with their content-derived seeds.  Planning
        the outer trial to find them consumes the trial's RNG draws, but the
        plan is cached until `step()` commits it, so calling this is
        trajectory-neutral.

        Mirrors what each strategy would launch inline: the whole warmup
        pool's probes, a pre-surrogate trial's sampled probe, or a scored
        trial's acquisition argmax -- widened to the top-`hw.spec_k`
        candidates (capped by the frozen window's remaining trials, exactly
        like `_prefetch_topk`) under the speculative strategy.  Items are
        filtered through `engine.pending_items`, so cached, duplicate, and
        bound-doomed probes drop out."""
        plan = self.loop.plan()
        if plan is None:
            return [], []
        if plan["kind"] == "warmup":
            cands = list(plan["pool"])
        elif plan["kind"] == "sample":
            cands = [plan["point"]]
        else:
            k = 1
            if self._spec_k > 1:
                k_cap = plan.get("k_cap")
                k = self._spec_k if k_cap is None else min(self._spec_k, k_cap)
            idx = score_topk(np.asarray(plan["utility"]), k)
            cands = [plan["pool"][int(i)] for i in idx]
        items, seeds, _ = self.engine.pending_items(cands)
        return items, seeds

    def result(self) -> CoDesignResult:
        """The session's `CoDesignResult` (final when `done`; the
        incumbent-so-far otherwise), with the engine + cache accounting for
        this session folded into `stats`."""
        engine = self.engine
        stats = dict(engine.stats)
        stats["spec_hit_rate"] = (
            stats["spec_hits"] / stats["spec_evaluated"]
            if stats["spec_evaluated"] else 0.0)
        stats["pruned_fraction"] = (
            stats["prune_pruned"] / stats["prune_considered"]
            if stats["prune_considered"] else 0.0)
        h0, m0, e0 = self._cache_counts0
        stats["cache_hits"] = engine.cache.hits - h0
        stats["cache_misses"] = engine.cache.misses - m0
        stats["cache_evictions"] = engine.cache.evictions - e0
        stats["cache_size"] = len(engine.cache)
        stats["prior_rows"] = self.n_prior
        feat = counters_snapshot()
        for key in ("hw_feat", "sw_feat", "sw_fwd"):
            for kind in ("hits", "misses"):
                name = f"{key}_{kind}"
                stats[name] = feat.get(name, 0) - self._feat_counts0.get(name, 0)
        return CoDesignResult(
            best_hw=self.best["hw"],
            best_mappings=self.best["maps"],
            best_model_edp=self.best["edp"],
            hw_result=self.loop.result,
            layer_edps=self.best["per_layer"],
            stats=stats,
        )

    def snapshot(self) -> dict:
        """Resumable session state as a plain dict: the outer loop's
        snapshot, the incumbent, the engine bookkeeping, and the (hw, layer)
        cache entries (the bound gate consults cache membership, so resuming
        without them could change when probes are censored)."""
        return {
            "loop": self.loop.snapshot(),
            "best": dict(self.best),
            "stats": dict(self.engine.stats),
            "speculated": list(self.engine._speculated),
            "cache": list(self.engine.cache.items()),
        }

    def restore(self, snap: dict) -> "SearchSession":
        """Load a `snapshot()` into this (freshly constructed, same engine
        config + layers) session.  The incumbent dict is updated in place --
        the gate/prune/eval closures hold a reference to it."""
        self.loop.restore(snap["loop"])
        self.n_prior = self.loop.n_prior
        self.best.update(snap["best"])
        self.engine.stats = dict(snap["stats"])
        self.engine._speculated = set(snap["speculated"])
        for key, value in snap["cache"]:
            self.engine.cache[key] = value
        return self


def codesign(
    layers: Sequence[ConvLayer],
    config: CodesignConfig | None = None,
    **legacy_kwargs,
) -> CoDesignResult:
    """Run the nested co-design search.

    The supported surface is `codesign(layers, config=CodesignConfig(...))`
    (or `CodesignEngine(config).run(layers)` to keep the cache across runs).
    The pre-config kwargs (`n_hw_trials=...`, `sw_pool=...`,
    `layer_batched=...`, ...) still work as a thin shim -- mapped through
    `config_from_legacy_kwargs`, result parity pinned in
    tests/test_config_api.py -- but emit a DeprecationWarning; the old-kwarg
    -> config-field table is in the README's "Search API" section."""
    if config is not None and not isinstance(config, CodesignConfig):
        # Loud break for pre-config positional callers (num_pes used to be
        # the second positional argument).
        raise TypeError(
            f"config must be a CodesignConfig, got {config!r}; legacy "
            f"options must be passed by keyword (num_pes=...)")
    if legacy_kwargs:
        if config is not None:
            raise TypeError(
                "pass either config= or legacy keyword arguments, not both")
        warnings.warn(
            "codesign(**kwargs) is deprecated: build a CodesignConfig and "
            "call codesign(layers, config=...) or "
            "CodesignEngine(config).run(layers) (see the README 'Search API' "
            "migration table)",
            DeprecationWarning, stacklevel=2)
        config = config_from_legacy_kwargs(**legacy_kwargs)
    return CodesignEngine(config).run(layers)
