"""Nested hardware/software co-design (paper §4.1, Fig. 1).

Outer loop: constrained BO over hardware configurations (50 trials in the paper).
Inner loop: for each candidate hardware, per-layer constrained BO over software
mappings (250 trials in the paper); layer-wise EDPs are summed into the model
EDP that the hardware optimizer sees.  The hardware objective is noisy (the
inner search is stochastic) -> noise kernel on; a hardware point with no
discoverable mapping for some layer is an *unknown-constraint* violation.

The per-layer searches of one hardware probe are independent, so on the JAX
backend `eval_hw` advances them *layer-batched*: one `bo_maximize_many` call
replaces the L sequential per-layer `optimize_software` runs, collapsing each
BO round's L evaluation dispatches and L surrogate refits into one fused
device program plus one batched GP fit (`codesign(layer_batched=...)`; the
default picks layer-batched exactly when the backend is "jax" and falls back
to the sequential path on NumPy).  The (hw, layer) result cache is shared by
both paths.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.bo import (BOResult, InfeasibleSpace, bo_maximize,
                           bo_maximize_many)
from repro.core.hwspace import HardwareSpace
from repro.core.swspace import SoftwareSpace, default_backend
from repro.timeloop.arch import HardwareConfig
from repro.timeloop.mapping import Mapping
from repro.timeloop.model import evaluate
from repro.timeloop.workloads import ConvLayer


@dataclasses.dataclass
class CoDesignResult:
    best_hw: HardwareConfig
    best_mappings: dict[str, Mapping]
    best_model_edp: float            # sum over layers, pJ*cycles
    hw_result: BOResult
    layer_edps: dict[str, float]


def optimize_software(
    hw: HardwareConfig,
    layer: ConvLayer,
    n_trials: int = 250,
    n_warmup: int = 30,
    pool_size: int = 150,
    acquisition: str = "lcb",
    lam: float = 1.0,
    surrogate: str = "gp_linear",
    seed: int = 0,
    batched: bool = True,
    backend: str | None = None,  # evaluation engine: "numpy" | "jax"
    gp_refit_every: int = 1,
) -> BOResult:
    space = SoftwareSpace(hw, layer, batched=batched, backend=backend)
    try:
        return bo_maximize(
            space,
            n_trials=n_trials,
            n_warmup=n_warmup,
            pool_size=pool_size,
            acquisition=acquisition,
            lam=lam,
            surrogate=surrogate,
            noisy=False,  # deterministic evaluator (paper §4.3)
            seed=seed,
            gp_refit_every=gp_refit_every,
        )
    except InfeasibleSpace:
        # No feasible mapping could even be sampled -> report an empty result;
        # the hardware level treats this as an unknown-constraint violation.
        return BOResult(None, -np.inf, [], [], [])


def optimize_software_many(
    hw: HardwareConfig,
    layers: Sequence[ConvLayer],
    n_trials: int = 250,
    n_warmup: int = 30,
    pool_size: int = 150,
    acquisition: str = "lcb",
    lam: float = 1.0,
    surrogate: str = "gp_linear",
    seed: int = 0,
    batched: bool = True,
    backend: str | None = None,
    gp_refit_every: int = 1,
) -> list[BOResult]:
    """Layer-batched twin of `optimize_software`: the L per-layer searches of
    one hardware probe advance in lockstep through `bo_maximize_many` (each
    seeded exactly as the sequential per-layer calls would be), one fused
    evaluation program + one stacked surrogate fit per BO round.  A layer with
    no sampleable mapping yields an empty `BOResult` (best_point None), same
    as `optimize_software`'s InfeasibleSpace handling."""
    spaces = [SoftwareSpace(hw, layer, batched=batched, backend=backend)
              for layer in layers]
    return bo_maximize_many(
        spaces,
        n_trials=n_trials,
        n_warmup=n_warmup,
        pool_size=pool_size,
        acquisition=acquisition,
        lam=lam,
        surrogate=surrogate,
        noisy=False,  # deterministic evaluator (paper §4.3)
        seed=seed,
        gp_refit_every=gp_refit_every,
    )


def codesign(
    layers: Sequence[ConvLayer],
    num_pes: int = 168,
    n_hw_trials: int = 50,
    n_sw_trials: int = 250,
    n_hw_warmup: int = 5,
    n_sw_warmup: int = 30,
    sw_pool: int = 150,
    hw_pool: int = 150,
    acquisition: str = "lcb",
    lam: float = 1.0,
    surrogate: str = "gp_linear",
    seed: int = 0,
    verbose: bool = False,
    batched: bool = True,
    use_cache: bool = True,
    backend: str | None = None,  # inner-engine selector: "numpy" | "jax"
    layer_batched: bool | None = None,  # None -> backend == "jax"
    gp_refit_every: int = 1,  # inner-loop GP amortization stride
) -> CoDesignResult:
    # Layer-batched inner search: one bo_maximize_many call per hardware probe
    # instead of L sequential optimize_software calls.  Defaults on for the
    # JAX engine (where the per-round work fuses into one device program and
    # one stacked GP fit) and off for NumPy (which keeps the existing
    # sequential path; pass layer_batched=True to force the lockstep engine).
    if layer_batched is None:
        layer_batched = batched and (backend or default_backend()) == "jax"
    inner_seed = [seed * 7919]
    best = {"edp": np.inf, "hw": None, "maps": None, "per_layer": None}
    # (hw, layer) -> (best mapping | None, edp).  The outer BO routinely
    # re-probes hardware points (acquisition argmax over a sampled pool repeats
    # configs, and pool candidates collide across trials); both are frozen
    # dataclasses, so the pair keys a dict and a hit skips the whole inner
    # 250-trial search.  The inner search is stochastic, so caching also makes
    # repeated probes of one hardware point consistent.  The cache is shared
    # by the sequential and layer-batched paths (same keys, same values).
    inner_cache: dict[tuple[HardwareConfig, ConvLayer], tuple[Mapping | None, float]] = {}

    def best_mapping(hw: HardwareConfig, layer: ConvLayer) -> tuple[Mapping | None, float]:
        key = (hw, layer)
        if not use_cache or key not in inner_cache:
            r = optimize_software(
                hw, layer,
                n_trials=n_sw_trials, n_warmup=n_sw_warmup, pool_size=sw_pool,
                acquisition=acquisition, lam=lam, surrogate=surrogate,
                seed=inner_seed[0], batched=batched, backend=backend,
                gp_refit_every=gp_refit_every,
            )
            if r.best_point is None:
                inner_cache[key] = (None, float("inf"))
            else:
                inner_cache[key] = (r.best_point, evaluate(hw, r.best_point, layer).edp)
        return inner_cache[key]

    def search_layers_batched(hw: HardwareConfig) -> None:
        """Fill the (hw, layer) cache for every layer this probe still needs,
        advancing all of those searches in one lockstep bo_maximize_many call
        (each layer seeded exactly as its sequential optimize_software call
        would be, so cached entries are interchangeable between paths)."""
        todo = list(dict.fromkeys(
            layer for layer in layers
            if not use_cache or (hw, layer) not in inner_cache))
        if not todo:
            return
        rs = optimize_software_many(
            hw, todo,
            n_trials=n_sw_trials, n_warmup=n_sw_warmup, pool_size=sw_pool,
            acquisition=acquisition, lam=lam, surrogate=surrogate,
            seed=inner_seed[0], batched=batched, backend=backend,
            gp_refit_every=gp_refit_every,
        )
        for layer, r in zip(todo, rs):
            if r.best_point is None:
                inner_cache[(hw, layer)] = (None, float("inf"))
            else:
                inner_cache[(hw, layer)] = (
                    r.best_point, evaluate(hw, r.best_point, layer).edp)

    def eval_hw(hw: HardwareConfig):
        inner_seed[0] += 1
        if layer_batched:
            search_layers_batched(hw)
        total_edp = 0.0
        maps: dict[str, Mapping] = {}
        per_layer: dict[str, float] = {}
        for layer in layers:
            m, edp = (inner_cache[(hw, layer)] if layer_batched
                      else best_mapping(hw, layer))
            if m is None:
                return None, False  # unknown constraint: no feasible mapping found
            total_edp += edp
            maps[layer.name] = m
            per_layer[layer.name] = edp
        if total_edp < best["edp"]:
            best.update(edp=total_edp, hw=hw, maps=maps, per_layer=per_layer)
        if verbose:
            print(f"  hw {hw.pe_mesh_x}x{hw.pe_mesh_y} "
                  f"lb=({hw.lb_input},{hw.lb_weight},{hw.lb_output}) "
                  f"-> model EDP {total_edp:.3e}")
        return -float(np.log10(total_edp)), True

    space = HardwareSpace(num_pes=num_pes, evaluate_fn=eval_hw)
    hw_result = bo_maximize(
        space,
        n_trials=n_hw_trials,
        n_warmup=n_hw_warmup,
        pool_size=hw_pool,
        acquisition=acquisition,
        lam=lam,
        surrogate=surrogate,
        noisy=True,  # inner search stochasticity (paper §4.2)
        seed=seed,
    )
    return CoDesignResult(
        best_hw=best["hw"],
        best_mappings=best["maps"],
        best_model_edp=best["edp"],
        hw_result=hw_result,
        layer_edps=best["per_layer"],
    )
