"""Typed, serializable search configuration for the co-design stack.

The nested search used to thread ~19 positional kwargs through
`codesign` -> `optimize_software(_many)` -> `bo_maximize(_many)` ->
`SoftwareSpace`; every new capability meant another knob at every layer.  This
module replaces that kwarg pipeline with a small set of frozen dataclasses:

  `SearchConfig`      one BO loop's budget + acquisition + surrogate
    `SWSearchConfig`    inner (software-mapping) defaults: 250 trials / 30 warmup
    `HWSearchConfig`    outer (hardware) defaults: 50 trials / 5 warmup + num_pes
  `EngineConfig`      evaluation machinery: backend, probe strategy,
                      GP-refit stride, batched protocol, cache, Pallas mode
  `CodesignConfig`    the composition (+ seed, verbose) -- the single object a
                      `CodesignEngine` runs; JSON round-trips via
                      `to_dict`/`from_dict`/`to_json`/`from_json`

Every enumerated string (backend / surrogate / acquisition / probe strategy /
Pallas mode) is validated HERE, at construction, through one shared
`validate_choice` site -- a bad value raises `ValueError` before any search
starts instead of threading silently to a deep call site.

`config_from_legacy_kwargs` maps the pre-config `codesign(**kwargs)` surface
onto a `CodesignConfig` (the deprecation shim in `repro.core.nested` uses it);
the old-kwarg -> config-field table lives in the README's "Search API" section.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any

BACKENDS = ("numpy", "jax")
SURROGATES = ("gp_linear", "gp_se", "rf")
ACQUISITIONS = ("lcb", "ei")
STRATEGIES = ("auto", "sequential", "layer_batched", "probe_fanout",
              "speculative")
PALLAS_MODES = ("jnp", "pallas", "interpret")
PRUNE_MODES = ("off", "safe", "aggressive")
EXECUTOR_KINDS = ("inline", "process")


def validate_choice(field: str, value, choices, optional: bool = False) -> None:
    """The one ValueError site for enumerated config strings."""
    if optional and value is None:
        return
    if value not in choices:
        allowed = " | ".join(repr(c) for c in choices)
        extra = " | None" if optional else ""
        raise ValueError(f"{field} must be one of {allowed}{extra}, "
                         f"got {value!r}")


def _validate_positive_int(field: str, value, minimum: int = 1) -> None:
    if not isinstance(value, int) or isinstance(value, bool) or value < minimum:
        raise ValueError(f"{field} must be an int >= {minimum}, got {value!r}")


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    """One constrained-BO loop: budget, acquisition, surrogate (paper §3).

    elite_k: candidate carry-forward width.  When > 0, each scored trial's
    acquisition pool is the fresh `pool_size` draw PLUS the previous scored
    trial's top-`elite_k` not-yet-evaluated candidates, so strong candidates
    survive pool resampling (the persistent-candidate trick of large-scale BO
    systems, cf. BoTorch/Vizier in PAPERS.md) and the acquisition argmax over
    the superset pool is a strictly better acquisition optimization.  It is
    also what gives the speculative outer loop its cache hits: a speculated
    candidate can actually be selected later instead of vanishing with its
    pool.  Applies to list-pool spaces (the hardware loop); 0 disables."""

    n_trials: int = 250
    n_warmup: int = 30
    pool_size: int = 150
    acquisition: str = "lcb"
    lam: float = 1.0
    surrogate: str = "gp_linear"
    elite_k: int = 0

    def __post_init__(self) -> None:
        validate_choice("acquisition", self.acquisition, ACQUISITIONS)
        validate_choice("surrogate", self.surrogate, SURROGATES)
        _validate_positive_int("n_trials", self.n_trials)
        _validate_positive_int("n_warmup", self.n_warmup, minimum=0)
        _validate_positive_int("pool_size", self.pool_size)
        _validate_positive_int("elite_k", self.elite_k, minimum=0)


@dataclasses.dataclass(frozen=True)
class SWSearchConfig(SearchConfig):
    """Inner per-layer software-mapping search (250 trials in the paper)."""


@dataclasses.dataclass(frozen=True)
class HWSearchConfig(SearchConfig):
    """Outer hardware search (50 trials / 5 warmup in the paper) plus the
    PE budget that parameterizes the hardware space itself.

    spec_k: fan-out width of the `strategy="speculative"` outer loop -- at each
    scored trial the top-k acquisition candidates are evaluated as one stacked
    multi-run program (the argmax feeds the BO history; the k-1 speculative
    results prefill the (hw, layer) cache).  Ignored by other strategies.

    prune: the semi-decoupled bound-and-prune pass (`timeloop.bounds`).  A
    scored probe whose summed per-layer EDP *lower bound* already exceeds the
    threshold below has its whole inner mapping search skipped (the engine's
    bound gate observes a censored, bound-derived utility instead, and the
    speculative fan-out never launches the search); the incumbent is only
    ever updated by true evaluations, so a vetoed probe provably cannot
    corrupt the final design:
      "off"         (default) no pruning
      "safe"        threshold = incumbent EDP exactly; bound <= truth, so a
                    vetoed probe provably cannot beat the incumbent
      "aggressive"  threshold = incumbent EDP * prune_margin -- margin < 1
                    also vetoes probes whose best case is within (1 - margin)
                    of the incumbent, trading completeness for speed; the
                    pool-level prune hook (`HardwareSpace.prune_fn`)
                    additionally drops bounded-out candidates before the
                    acquisition ranks them
    prune_margin: the "aggressive" threshold multiplier (> 0; ignored by
    "safe", which always uses exactly 1.0).  Pool-level removal is reserved
    for "aggressive" because redirecting a doomed selection into a different
    full search is wall-clock neutral -- the measured speedup of "safe"
    comes from censoring doomed selections, which pool removal would
    starve.

    warm_start: cross-run transfer (`repro.service`).  When True, a service
    request consumes the workload set's recorded trial history
    (`TrialHistory`, keyed by `history_key`) as prior observations seeding
    the outer GP/classifier before the first warmup probe, and exact
    design-store misses fall back to an approximate nearest-neighbor lookup
    whose mapping seeds the inner search as a warm-start incumbent
    (re-evaluated exactly on the target hardware; `warm_hits` in stats).
    With no history and no store the search is bit-identical to
    warm_start=False -- priors only ever ADD surrogate data.
    warm_start_rows: cap on consumed prior rows (most recent first).
    warm_start_bound_mean: additionally center the outer GP on the EDP
    lower bound (`timeloop.bounds`: m(x) = -log10(sum of per-layer bounds),
    an ordering-accurate upper bound on utility); the GP fits residuals
    y - m(x) and posteriors add m back.  Off by default: it changes the
    search trajectory even without history (an opt-in prior model, not a
    pure transfer knob)."""

    n_trials: int = 50
    n_warmup: int = 5
    num_pes: int = 168
    spec_k: int = 4
    elite_k: int = 4  # carry-forward on by default for the outer loop
    prune: str = "off"
    prune_margin: float = 1.0
    warm_start: bool = False
    warm_start_rows: int = 256
    warm_start_bound_mean: bool = False

    def __post_init__(self) -> None:
        super().__post_init__()
        _validate_positive_int("num_pes", self.num_pes)
        _validate_positive_int("spec_k", self.spec_k)
        validate_choice("prune", self.prune, PRUNE_MODES)
        if not (isinstance(self.prune_margin, (int, float))
                and not isinstance(self.prune_margin, bool)
                and self.prune_margin > 0.0):
            raise ValueError(
                f"prune_margin must be a number > 0, got {self.prune_margin!r}")
        for field in ("warm_start", "warm_start_bound_mean"):
            if not isinstance(getattr(self, field), bool):
                raise ValueError(
                    f"{field} must be a bool, got {getattr(self, field)!r}")
        _validate_positive_int("warm_start_rows", self.warm_start_rows)


@dataclasses.dataclass(frozen=True)
class ExecutorConfig:
    """Where stacked inner-search dispatches run (`repro.parallel.executor`).

    kind         "inline"   run each submitted search spec synchronously in
                            the learner process (the historical behavior --
                            zero overhead, zero processes)
                 "process"  a pool of persistent spawn-started worker
                            processes pulls whole stacked k*L-run searches
                            from a task queue and returns (mapping, EDP)
                            entries.  Content-derived probe seeds make the
                            results bit-identical to inline for every worker
                            count (pinned against the goldens).
    n_workers    worker-pool width for kind="process"; 0 (default) resolves
                 to min(4, cpu_count).
    chunk_items  split each submitted spec into chunks of at most this many
                 (hw, layer) items so one stacked dispatch spreads across
                 idle workers; 0 (default) splits evenly across the pool
                 (ceil(n_items / n_workers)).  Chunking only regroups which
                 runs share a stacked fit -- the same composition freedom the
                 service's cross-request fusion already exercises -- so it
                 cannot change results in the pinned Cholesky regime.
    """

    kind: str = "inline"
    n_workers: int = 0
    chunk_items: int = 0

    def __post_init__(self) -> None:
        validate_choice("kind", self.kind, EXECUTOR_KINDS)
        _validate_positive_int("n_workers", self.n_workers, minimum=0)
        _validate_positive_int("chunk_items", self.chunk_items, minimum=0)

    def resolve_workers(self) -> int:
        if self.n_workers:
            return self.n_workers
        return max(1, min(4, os.cpu_count() or 1))


def _coerce_executor(obj, owner: str) -> ExecutorConfig:
    """Accept an ExecutorConfig, a JSON dict (the from_dict path), or None."""
    if obj is None:
        return ExecutorConfig()
    if isinstance(obj, ExecutorConfig):
        return obj
    if isinstance(obj, dict):
        try:
            return ExecutorConfig(**obj)
        except TypeError as e:
            raise ValueError(f"invalid {owner}.executor dict: {e}") from None
    raise ValueError(f"{owner}.executor must be an ExecutorConfig or dict, "
                     f"got {obj!r}")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Evaluation machinery, orthogonal to either loop's search budget.

    backend         "numpy" | "jax" | None (None -> $REPRO_BACKEND or "numpy")
    strategy        probe-evaluation strategy for the nested driver:
                      "sequential"    L per-layer searches per hardware probe
                      "layer_batched" one lockstep `bo_maximize_many` per probe
                      "probe_fanout"  layer_batched + the outer warmup's H
                                      independent probes fanned out as ONE
                                      H*L-run stacked `bo_maximize_many`
                      "speculative"   probe_fanout + per scored outer trial the
                                      top-`hw.spec_k` acquisition candidates
                                      fan out as one k*L-run stacked program
                                      (argmax consumed, the rest cached)
                      "auto"          layer_batched on jax, sequential on numpy
    gp_refit_every  inner-loop surrogate refit stride (amortization)
    hw_gp_refit_every
                    OUTER-loop surrogate refit stride.  Trials inside one
                    refit window score their pools with the same posterior,
                    so with candidate carry-forward (`hw.elite_k`) the top-k
                    of a window's first trial is exactly the q-batch the
                    following trials select from -- the regime where
                    `strategy="speculative"`'s prefetch turns into cache hits
                    (cf. Vizier's parallel suggestions from one posterior).
                    1 (default) refits every trial like the paper.
    batched         expose the batched evaluation protocol to the BO loop
    use_cache       share the (hw, layer) -> best-mapping cache across probes
    pallas_mode     inner-kernel dispatch: "jnp" | "pallas" | "interpret" |
                    None (None -> jnp off-TPU, pallas on TPU)
    gp_rank1_updates
                    amortize the OUTER surrogate between aligned refits: each
                    scored trial's feasible observation is appended to the GP
                    through an O(n^2) rank-1 Cholesky border update (frozen
                    hyperparameters) instead of waiting for the next O(n^3)
                    refit, and the posterior reuses the cached factor.  Off by
                    default: a mid-window posterior update changes frozen-
                    window trajectories (fresher, but not bit-identical to
                    the paper's refit-every-trial schedule).
    cache_entries   LRU bound on the engine's (hw, layer) -> best-mapping
                    cache (0 = unbounded, the historical behavior).  Content-
                    derived probe seeds make eviction result-preserving under
                    prune="off" (a re-search reproduces the evicted entry
                    bit-for-bit); with the bound gate on, eviction can change
                    *when* probes are censored, so bounded runs are only
                    guaranteed identical to unbounded ones while nothing is
                    evicted.  Long-lived service processes set this
                    (`ServiceConfig.cache_entries`).
    executor        where stacked inner-search dispatches run
                    (`ExecutorConfig`; dicts from the JSON surface are
                    coerced).  Purely a placement knob: it cannot enter the
                    design-store key because it cannot change results.
    """

    backend: str | None = None
    strategy: str = "auto"
    gp_refit_every: int = 1
    hw_gp_refit_every: int = 1
    batched: bool = True
    use_cache: bool = True
    pallas_mode: str | None = None
    gp_rank1_updates: bool = False
    cache_entries: int = 0
    executor: ExecutorConfig = dataclasses.field(
        default_factory=ExecutorConfig)

    def __post_init__(self) -> None:
        object.__setattr__(self, "executor",
                           _coerce_executor(self.executor, "EngineConfig"))
        validate_choice("backend", self.backend, BACKENDS, optional=True)
        validate_choice("strategy", self.strategy, STRATEGIES)
        validate_choice("pallas_mode", self.pallas_mode, PALLAS_MODES,
                        optional=True)
        _validate_positive_int("gp_refit_every", self.gp_refit_every)
        _validate_positive_int("hw_gp_refit_every", self.hw_gp_refit_every)
        _validate_positive_int("cache_entries", self.cache_entries, minimum=0)
        if self.strategy in ("probe_fanout", "speculative") and not self.use_cache:
            raise ValueError(
                f"strategy={self.strategy!r} requires use_cache=True: the "
                "fan-out prefills the (hw, layer) cache that probe evaluation "
                "reads")

    def resolve_backend(self) -> str:
        from repro.core.swspace import default_backend

        return self.backend or default_backend()

    def resolve_strategy(self) -> str:
        """Concrete strategy name ('auto' resolved against the backend)."""
        if self.strategy != "auto":
            return self.strategy
        if self.batched and self.resolve_backend() == "jax":
            return "layer_batched"
        return "sequential"


@dataclasses.dataclass(frozen=True)
class CodesignConfig:
    """The full nested-search configuration a `CodesignEngine` runs."""

    sw: SWSearchConfig = dataclasses.field(default_factory=SWSearchConfig)
    hw: HWSearchConfig = dataclasses.field(default_factory=HWSearchConfig)
    engine: EngineConfig = dataclasses.field(default_factory=EngineConfig)
    seed: int = 0
    verbose: bool = False

    def __post_init__(self) -> None:
        for field, cls in (("sw", SWSearchConfig), ("hw", HWSearchConfig),
                           ("engine", EngineConfig)):
            if not isinstance(getattr(self, field), cls):
                raise ValueError(
                    f"{field} must be a {cls.__name__}, "
                    f"got {getattr(self, field)!r}")

    # --- serialization ----------------------------------------------------------

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "CodesignConfig":
        """Inverse of `to_dict`; sections and fields may be omitted (defaults
        apply), unknown keys raise ValueError."""
        d = dict(d)
        try:
            sw = SWSearchConfig(**d.pop("sw", None) or {})
            hw = HWSearchConfig(**d.pop("hw", None) or {})
            engine = EngineConfig(**d.pop("engine", None) or {})
            return cls(sw=sw, hw=hw, engine=engine, **d)
        except TypeError as e:  # unknown field name in some section
            raise ValueError(f"invalid CodesignConfig dict: {e}") from None

    def to_json(self, **json_kw) -> str:
        json_kw.setdefault("indent", 2)
        json_kw.setdefault("sort_keys", True)
        return json.dumps(self.to_dict(), **json_kw)

    @classmethod
    def from_json(cls, s: str) -> "CodesignConfig":
        return cls.from_dict(json.loads(s))


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Co-design service driver configuration (`repro.service`).

    max_slots      concurrent search sessions advanced per scheduler tick
                   (the slot-admission width; queued requests wait for a
                   free slot, like `launch/serve.py`'s decode batch)
    fuse           fuse every admitted session's pending inner searches into
                   ONE cross-request stacked `bo_maximize_many` dispatch per
                   tick (False: one dispatch per session per tick -- the
                   ablation baseline; results are identical either way)
    store_dir      persistent design-store directory (None: no store).  The
                   store is keyed by content hash of (hw, layer, search
                   config, probe seed), so hits are exact replays.
    cache_entries  LRU bound applied to each request's engine (hw, layer)
                   cache when the request's own `EngineConfig.cache_entries`
                   is 0 -- long-lived service processes must not grow
                   memory without bound.
    executor       where the scheduler's fused per-tick dispatches run
                   (`ExecutorConfig`).  kind="process" also overlaps ticks:
                   sessions whose pending work is still in flight park while
                   sessions with resolved results step immediately.
    store_max_entries  disk-footprint bound for the design store: after each
                   request retires, entries beyond this cap are evicted
                   oldest-first (`DesignStore.prune`).  0 = unbounded.
    history_dir    cross-run trial-history directory (None: no history).
                   When set, every non-portfolio request appends its finished
                   outer trials under its workload set's `history_key`, and
                   requests with `HWSearchConfig.warm_start` replay those
                   rows as outer-GP prior observations
                   (`repro.service.store.TrialHistory`).
    """

    max_slots: int = 4
    fuse: bool = True
    store_dir: str | None = None
    cache_entries: int = 65536
    executor: ExecutorConfig = dataclasses.field(
        default_factory=ExecutorConfig)
    store_max_entries: int = 0
    history_dir: str | None = None

    def __post_init__(self) -> None:
        object.__setattr__(self, "executor",
                           _coerce_executor(self.executor, "ServiceConfig"))
        _validate_positive_int("max_slots", self.max_slots)
        _validate_positive_int("cache_entries", self.cache_entries, minimum=0)
        _validate_positive_int("store_max_entries", self.store_max_entries,
                               minimum=0)
        if self.store_dir is not None and not isinstance(self.store_dir, str):
            raise ValueError(
                f"store_dir must be a str or None, got {self.store_dir!r}")
        if self.history_dir is not None \
                and not isinstance(self.history_dir, str):
            raise ValueError(
                f"history_dir must be a str or None, got {self.history_dir!r}")

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "ServiceConfig":
        try:
            return cls(**d)
        except TypeError as e:
            raise ValueError(f"invalid ServiceConfig dict: {e}") from None


# --- legacy kwarg surface --------------------------------------------------------

# old codesign kwarg -> (section, config field); None section = CodesignConfig
# top level.  This is the migration table (also rendered in the README).
LEGACY_KWARG_MAP: dict[str, tuple[str | None, str]] = {
    "num_pes": ("hw", "num_pes"),
    "n_hw_trials": ("hw", "n_trials"),
    "n_hw_warmup": ("hw", "n_warmup"),
    "hw_pool": ("hw", "pool_size"),
    "n_sw_trials": ("sw", "n_trials"),
    "n_sw_warmup": ("sw", "n_warmup"),
    "sw_pool": ("sw", "pool_size"),
    "backend": ("engine", "backend"),
    "batched": ("engine", "batched"),
    "use_cache": ("engine", "use_cache"),
    "gp_refit_every": ("engine", "gp_refit_every"),
    "seed": (None, "seed"),
    "verbose": (None, "verbose"),
    # acquisition / lam / surrogate applied to BOTH loops (the legacy API had
    # one knob); layer_batched maps onto engine.strategy (see below).
}
_SHARED_SEARCH_KEYS = ("acquisition", "lam", "surrogate")


def config_from_legacy_kwargs(**kw) -> CodesignConfig:
    """Map the pre-config `codesign(**kwargs)` surface to a `CodesignConfig`.

    `layer_batched` (bool | None) becomes `engine.strategy`:
    None -> "auto", True -> "layer_batched", False -> "sequential"."""
    sections: dict[str, dict] = {"sw": {}, "hw": {}, "engine": {}, None: {}}
    if "layer_batched" in kw:
        lb = kw.pop("layer_batched")
        sections["engine"]["strategy"] = (
            "auto" if lb is None else "layer_batched" if lb else "sequential")
    for key in _SHARED_SEARCH_KEYS:
        if key in kw:
            v = kw.pop(key)
            sections["sw"][key] = v
            sections["hw"][key] = v
    for key, value in kw.items():
        if key not in LEGACY_KWARG_MAP:
            raise TypeError(
                f"codesign() got an unexpected keyword argument {key!r}; "
                f"valid legacy kwargs: {sorted(LEGACY_KWARG_MAP) + ['layer_batched', *_SHARED_SEARCH_KEYS]}")
        section, field = LEGACY_KWARG_MAP[key]
        sections[section][field] = value
    return CodesignConfig(
        sw=SWSearchConfig(**sections["sw"]),
        hw=HWSearchConfig(**sections["hw"]),
        engine=EngineConfig(**sections["engine"]),
        **sections[None],
    )
