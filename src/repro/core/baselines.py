"""Search baselines from the paper's evaluation (§5.1 Baselines).

* constrained random search -- "repeatedly takes the first random sample in the
  design space that satisfies the constraints".
* relax-and-round BO        -- out-of-the-box BO in a continuous unit cube,
  rounded to the nearest valid discrete design point.
* TVM-style learned search  -- a gradient-boosted-trees cost model (XGBoost
  analogue) trained online, with epsilon-greedy batched candidate selection,
  mirroring Chen et al. (2018).
"""

from __future__ import annotations

import numpy as np

from repro.core.bo import BOResult
from repro.core.gp import GP
from repro.core.trees import GradientBoostedTrees
from repro.timeloop.mapping import LEVELS, Mapping, _prod
from repro.timeloop.workloads import DIMS, divisors


def random_search(space, n_trials: int = 250, seed: int = 0) -> BOResult:
    rng = np.random.default_rng(seed)
    result = BOResult(None, -np.inf, [], [], [])
    for _ in range(n_trials):
        p = space.sample(rng)
        for _ in range(100_000):  # first sample satisfying the known constraints
            if space.is_valid(p):
                break
            p = space.sample(rng)
        value, feasible = space.evaluate(p)
        result.points.append(p)
        if feasible and value > result.best_value:
            result.best_value, result.best_point = value, p
        result.values.append(value if feasible else -np.inf)
        if not feasible:
            result.n_infeasible += 1
        result.history.append(result.best_value)
    return result


def tvm_style_search(
    space, n_trials: int = 250, n_warmup: int = 30, pool_size: int = 150,
    epsilon: float = 0.1, seed: int = 0,
) -> BOResult:
    """Learned-cost-model search: GBT regressor ranks a candidate pool; with
    probability epsilon explore randomly (TVM's exploration knob)."""
    rng = np.random.default_rng(seed)
    result = BOResult(None, -np.inf, [], [], [])
    X, y = [], []

    def observe(p):
        value, feasible = space.evaluate(p)
        result.points.append(p)
        if feasible:
            X.append(space.features(p))
            y.append(value)
            if value > result.best_value:
                result.best_value, result.best_point = value, p
            result.values.append(value)
        else:
            result.n_infeasible += 1
            result.values.append(-np.inf)
        result.history.append(result.best_value)

    def sample_valid():
        while True:
            p = space.sample(rng)
            if space.is_valid(p):
                return p

    for _ in range(min(n_warmup, n_trials)):
        observe(sample_valid())
    model = None
    for t in range(len(result.history), n_trials):
        if len(y) >= 4:
            model = GradientBoostedTrees(seed=seed).fit(np.stack(X), np.asarray(y))
        if model is None or rng.random() < epsilon:
            observe(sample_valid())
            continue
        pool = [sample_valid() for _ in range(pool_size)]
        preds = model.predict(np.stack([space.features(p) for p in pool]))
        observe(pool[int(np.argmax(preds))])
    return result


# --- relax-and-round BO ------------------------------------------------------


def _round_mapping(u: np.ndarray, space) -> Mapping:
    """Decode a continuous point in [0,1]^D to the nearest *valid* mapping
    (the paper's relax-and-round baseline): each dim's factor chain is picked
    by rounding into the capacity-admissible divisor lists (nearest-valid
    repair); loop orders come from argsorting continuous keys."""
    layer, hw = space.layer, space.hw
    idx = 0
    per_level = {lvl: [1] * len(DIMS) for lvl in LEVELS}

    def lb_ok(fl):
        r, s, p, q, c, k = fl
        return (r * s * c * k <= hw.lb_weight
                and layer.input_extent(p, r) * layer.input_extent(q, s) * c <= hw.lb_input
                and p * q * k <= hw.lb_output)

    for di, d in enumerate(DIMS):
        rem = layer.dim(d)
        for lvl in ("lb", "sx", "sy", "gb"):
            ds = divisors(rem)
            if lvl == "lb":
                cands = []
                for f in ds:
                    trial = list(per_level["lb"])
                    trial[di] = f
                    if lb_ok(trial):
                        cands.append(f)
                ds = cands or [1]
            elif lvl == "sx":
                cap = hw.pe_mesh_x // _prod(per_level["sx"])
                ds = [f for f in ds if f <= cap] or [1]
            elif lvl == "sy":
                cap = hw.pe_mesh_y // _prod(per_level["sy"])
                ds = [f for f in ds if f <= cap] or [1]
            f = ds[min(int(u[idx] * len(ds)), len(ds) - 1)]
            per_level[lvl][di] = f
            rem //= f
            idx += 1
        per_level["dram"][di] = rem
    orders = []
    for _ in range(3):
        keys = u[idx : idx + len(DIMS)]
        orders.append(tuple(DIMS[i] for i in np.argsort(keys)))
        idx += len(DIMS)
    return Mapping(
        factors=tuple(tuple(per_level[lvl]) for lvl in LEVELS),
        order_lb=orders[0],
        order_gb=orders[1],
        order_dram=orders[2],
    )


def relax_round_bo(
    space, n_trials: int = 250, n_warmup: int = 30, pool_size: int = 150,
    lam: float = 1.0, seed: int = 0,
) -> BOResult:
    """Out-of-the-box BO baseline: SE-kernel GP over the continuous relaxation,
    LCB acquisition over a random continuous pool, round to valid parameters.
    Infeasible rounded points score a large penalty (the standard treatment)."""
    rng = np.random.default_rng(seed)
    dim = 4 * len(DIMS) + 3 * len(DIMS)
    result = BOResult(None, -np.inf, [], [], [])
    U, y = [], []
    PENALTY = None

    def observe(u):
        nonlocal PENALTY
        m = _round_mapping(u, space)
        value, feasible = space.evaluate(m)
        result.points.append(m)
        if feasible:
            if value > result.best_value:
                result.best_value, result.best_point = value, m
            result.values.append(value)
            if PENALTY is None or value - 2.0 < PENALTY:
                PENALTY = value - 2.0
        else:
            result.n_infeasible += 1
            result.values.append(-np.inf)
        U.append(u)
        y.append(value if feasible else np.nan)
        result.history.append(result.best_value)

    for _ in range(min(n_warmup, n_trials)):
        observe(rng.random(dim))
    for _ in range(len(result.history), n_trials):
        yy = np.asarray(y, dtype=np.float64)
        fill = PENALTY if PENALTY is not None else -20.0
        yy = np.where(np.isnan(yy), fill, yy)
        gp = GP(kind="se", noisy=True).fit(np.stack(U), yy)
        pool = rng.random((pool_size, dim))
        mu, var = gp.posterior(pool)
        observe(pool[int(np.argmax(mu + lam * np.sqrt(var)))])
    return result
