"""Beyond-paper: the paper's constrained-BO engine retargeted at THIS
framework's own performance knobs (sharding layout, mesh split, remat,
flash-attention block sizes).

The black box is `lower().compile()` + roofline analysis (minutes per sample on
this container -- genuinely expensive, like the paper's simulator), the
objective is estimated step time (the EDP analogue: we minimize time at fixed
hardware, i.e. the delay term), known constraints (divisibility, axis fit) are
input constraints, and compile failures / OOM are unknown constraints handled
by the GP classifier.  See EXPERIMENTS.md §Perf for results.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.parallel.sharding import AxisRules

_MESH_SPLITS = [(64, 4), (32, 8), (16, 16), (8, 32), (4, 64)]
_BLOCKS = [256, 512, 1024, 2048]


@dataclasses.dataclass(frozen=True)
class TuneConfig:
    mesh_data: int = 16
    mesh_model: int = 16
    fsdp: bool = True
    remat: str = "block"          # "none" | "block"
    flash_bq: int = 1024
    flash_bk: int = 1024

    def rules(self) -> AxisRules:
        return AxisRules(fsdp="data" if self.fsdp else None)


@dataclasses.dataclass
class TuneSpace:
    """Constrained search space over TuneConfig for one (cfg, shape) cell."""

    cfg: ModelConfig
    shape: ShapeConfig
    total_chips: int = 256
    name: str = "autotune"

    feature_dim: int = 7

    def sample(self, rng) -> TuneConfig:
        d, m = _MESH_SPLITS[rng.integers(len(_MESH_SPLITS))]
        return TuneConfig(
            mesh_data=d,
            mesh_model=m,
            fsdp=bool(rng.integers(2)),
            remat="block" if rng.integers(2) else "none",
            flash_bq=int(_BLOCKS[rng.integers(len(_BLOCKS))]),
            flash_bk=int(_BLOCKS[rng.integers(len(_BLOCKS))]),
        )

    def is_valid(self, t: TuneConfig) -> bool:
        # Known input constraints: mesh must multiply out; batch divisible by
        # the data axis; TP dims divisible by the model axis; flash blocks
        # cannot exceed the sequence.
        if t.mesh_data * t.mesh_model != self.total_chips:
            return False
        if self.shape.global_batch % t.mesh_data:
            return False
        for dim in (self.cfg.d_model, self.cfg.d_ff or self.cfg.d_model):
            if dim % t.mesh_model:
                return False
        if t.flash_bq > self.shape.seq_len or t.flash_bk > self.shape.seq_len:
            return False
        return True

    def features(self, t: TuneConfig) -> np.ndarray:
        return np.array([
            np.log2(t.mesh_data),
            np.log2(t.mesh_model),
            float(t.fsdp),
            1.0 if t.remat == "block" else 0.0,
            np.log2(t.flash_bq),
            np.log2(t.flash_bk),
            np.log2(t.mesh_data) - np.log2(max(t.mesh_model, 1)),
        ], np.float64)

    def evaluate(self, t: TuneConfig) -> tuple[float | None, bool]:
        import jax
        from repro.launch import dryrun as DR

        cfg = dataclasses.replace(
            self.cfg, remat=t.remat, flash_block_q=t.flash_bq,
            flash_block_k=t.flash_bk)
        mesh = jax.make_mesh(
            (t.mesh_data, t.mesh_model), ("data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 2)
        try:
            lowered = DR.lower_cell(cfg, self.shape, mesh, t.rules())
            rec = DR.analyze(lowered, cfg, self.shape, mesh, t.rules())
        except Exception:
            return None, False      # unknown constraint: compile failure
        if not rec["memory"]["fits_16g"]:
            return None, False      # unknown constraint: exceeds HBM
        step = rec["roofline"]["step_time_s"]
        self.last_record = rec
        return -float(np.log10(step)), True


def autotune(cfg: ModelConfig, shape: ShapeConfig, n_trials: int = 12,
             n_warmup: int = 4, pool_size: int = 32, seed: int = 0):
    """Run constrained BO over the tune space; returns (best TuneConfig, BOResult)."""
    from repro.core.bo import bo_maximize

    space = TuneSpace(cfg, shape)
    result = bo_maximize(space, n_trials=n_trials, n_warmup=n_warmup,
                         pool_size=pool_size, acquisition="lcb", lam=1.0,
                         surrogate="gp_linear", noisy=False, seed=seed)
    return result.best_point, result
