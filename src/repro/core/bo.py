"""Generic constrained Bayesian optimization loop (paper §3, §4).

The loop implements the paper's scheme exactly:
  * warmup with random feasible samples (5 HW / 30 SW in the paper),
  * fit the objective surrogate on feasible observations (linear kernel on
    engineered features; noise kernel only when the evaluator is noisy),
  * if any *output*-infeasible points have been observed, fit the SE-kernel GP
    classifier and weight the acquisition by P(C(x)) (Gelbart et al. 2014),
  * optimize the acquisition by rejection sampling: pool `pool_size` candidates
    that satisfy all input constraints, pick the acquisition argmax,
  * evaluate, record, repeat for `n_trials`.

Two pool-construction refinements apply to list-pool spaces (the hardware
loop): *candidate carry-forward* (`cfg.elite_k` > 0 keeps the previous scored
trial's best unevaluated candidates in the next trial's pool, so the
acquisition optimizer has memory across pool resamples) and *frozen refit
windows* (`gp_refit_every` > 1 reuses one pool per refit window with consumed
candidates masked out, turning the window into one batched acquisition round
-- the q-batch semantics of BoTorch/Vizier-style parallel suggestion, and the
regime where the nested driver's speculative prefetch becomes exact).  Packed
software (MappingBatch) pools are untouched by both.

Spaces may implement the *batched evaluation protocol* — `supports_batch`
(truthy), `sample_pool(rng, n)`, `features_batch(pool)`, `evaluate_batch(pool)`
(see `repro.timeloop.batch`) — in which case warmup draws and the per-trial
acquisition pool are sampled, featurized, and scored as whole arrays instead of
one candidate at a time (both the software-mapping space and the hardware
space implement it; the hardware space's `evaluate_batch` still loops — its
evaluator is a full nested search); spaces without the protocol transparently
fall back to the scalar path.

Spaces that additionally expose `supports_device` + `features_batch_device`
(the JAX engine, `repro.timeloop.batch_jax`) get *device-resident* pool
scoring: featurization, GP posterior, acquisition, and the feasibility
classifier all stay on-device as one fused chain per trial, and only the
argmax index (plus the winner's feature row) crosses back to the host.
Everything on the host side of that boundary is kept strictly NumPy —
`np.asarray` at every device edge — so no host computation silently promotes
to device arrays with a blocking transfer per trial.

`bo_maximize_many` is the *multi-run* engine: it advances L independent
searches (the nested scheme's per-layer software searches of one hardware
probe) in lockstep, so per-round work that the sequential path repeats L times
collapses into one batched program each — one fused device evaluation over all
runs' candidate pools (`LayerStackSpace` packs them into a single (L*B, 5, 6)
batch), one batched GP fit over all runs' surrogates (`GPStack`, a `lax.map`
program), one stacked posterior + acquisition + classifier chain.  Each run
keeps its own RNG stream
(seeded exactly as `bo_maximize(seed=...)` would be), its own observation
history, and its own early-stop mask, so the lockstep engine reproduces L
sequential `bo_maximize` calls run-for-run.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, Sequence

import numpy as np

from repro.core.acquisition import make_acquisition, make_acquisition_device
from repro.core.config import BACKENDS, SearchConfig, SWSearchConfig
from repro.core.gp import (GP, GPClassifier, GPClassifierStack, GPStack,
                           apply_prior_mean)
from repro.core.trees import RandomForestSurrogate


class InfeasibleSpace(RuntimeError):
    """Raised when input-constraint rejection sampling cannot find any valid
    point -- the search space itself is (empirically) empty.  At the hardware
    level this is the paper's *unknown constraint*."""


@contextlib.contextmanager
def _backend_override(spaces, backend: str):
    """Engine override for spaces that carry one, scoped to one run -- the
    callers' spaces are restored on the way out.  Unknown values and spaces
    without backend selection are reported, never ignored.  Shared by
    `bo_maximize` and `bo_maximize_many`."""
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
    for s in spaces:
        if not hasattr(s, "backend"):
            raise ValueError(
                f"space {getattr(s, 'name', s)!r} does not support "
                "backend selection")
    prev = [s.backend for s in spaces]
    for s in spaces:
        s.backend = backend
    try:
        yield
    finally:
        for s, b in zip(spaces, prev):
            s.backend = b


@dataclasses.dataclass
class BOResult:
    best_point: Any
    best_value: float                 # utility (maximized): -log10(EDP)
    history: list[float]              # best-so-far utility per trial
    values: list[float]               # raw utility per trial (-inf if infeasible)
    points: list[Any]
    n_infeasible: int = 0


def score_topk(utility, k: int) -> np.ndarray:
    """Indices of the k largest utilities in DESCENDING order -- the ranking
    sibling of `GPStack.score_device`'s fused argmax, used by the speculative
    outer loop to pick its fan-out candidates.  The sort is stable, so ties
    rank by pool index and entry 0 is exactly `np.argmax(utility)` -- the
    candidate the BO trial itself consumes."""
    utility = np.asarray(utility)
    k = max(1, min(int(k), len(utility)))
    return np.argsort(-utility, kind="stable")[:k]


def _prefetch_topk(space, pool, utility, k_cap: int | None = None) -> None:
    """Speculative-prefetch hook: spaces exposing `prefetch_topk_fn` (+ a
    `prefetch_topk` width > 1) get the trial's pool candidates ranked by
    acquisition utility, best first, BEFORE the argmax is evaluated.  The
    nested driver's "speculative" strategy injects it on the hardware space to
    fan the top-k probes' inner searches out as one stacked multi-run program;
    entry 0 is the trial's own argmax, the rest are speculation.  Purely an
    observer: no RNG is consumed and the trial's own selection is untouched,
    so the BO trajectory is exactly the un-hooked one.

    `k_cap` bounds the width when the loop KNOWS how much speculation can
    still be consumed -- inside a frozen refit window only the window's
    remaining trials can select a speculated candidate, so anything wider is
    guaranteed waste."""
    fn = getattr(space, "prefetch_topk_fn", None)
    k = int(getattr(space, "prefetch_topk", 0) or 0)
    if k_cap is not None:
        k = min(k, k_cap)
    if fn is None or k <= 1:
        return
    idx = score_topk(utility, k)
    fn([pool[int(i)] for i in idx])


def _resolve_search_config(config, overrides) -> SearchConfig:
    """Normalize (config object, field overrides) to one validated
    `SearchConfig`.  Overrides are the config's own field names
    (n_trials/n_warmup/pool_size/acquisition/lam/surrogate) -- the pre-config
    kwarg surface -- applied through `dataclasses.replace`, so the replaced
    config re-validates and an unknown name raises TypeError."""
    if config is not None and not isinstance(config, SearchConfig):
        # Loud break for pre-config positional callers (n_trials used to be
        # the second positional argument).
        raise TypeError(
            f"config must be a SearchConfig (e.g. SWSearchConfig), got "
            f"{config!r}; pass search fields by keyword (n_trials=...)")
    cfg = config if config is not None else SWSearchConfig()
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


class BOLoop:
    """One constrained-BO search as an explicit, resumable state machine.

    `bo_maximize(...)` is exactly `BOLoop(...).run()`: all of the loop's
    state -- RNG stream, observation history, surrogate/classifier, frozen
    pool window, elite carry-forward -- lives on the instance instead of in
    closure variables, and each trial splits into two halves:

      `plan()`    advance the loop up to (but not through) its next
                  evaluation: refit the surrogate if due, sample the trial's
                  candidate pool, score it, and return a *plan* describing
                  what the trial is about to evaluate.  All RNG consumption
                  happens here.  Idempotent: repeated calls return the same
                  pending plan.
      `commit()`  execute the pending plan: evaluate the selected
                  candidate(s), record observations, update elites, fire the
                  speculative-prefetch hook and the callback.

    The split is what lets an external scheduler (the co-design service)
    inspect what a session is about to evaluate -- `plan()["pool"]` /
    the scored plan's ranked utilities -- and pre-fill evaluation caches
    across many concurrent loops before any of them commits.  `plan()`
    followed by `commit()` performs the exact statement sequence of the
    historical inline loop, so stepped execution is bit-identical to
    `run()`, which is bit-identical to the pre-refactor `bo_maximize`.

    `snapshot()`/`restore()` round-trip the loop through a plain dict (no
    live plan may be outstanding): the RNG state, histories, incumbent, and
    frozen window are copied, and the surrogate/classifier are *refit* from
    the recorded fit boundary on restore (model fits are deterministic given
    their data, so the restored loop continues bit-identically).
    """

    def __init__(
        self,
        space,
        config: SearchConfig | None = None,
        *,
        noisy: bool = False,
        seed: int = 0,
        gp_refit_every: int = 1,
        gp_rank1: bool = False,
        callback: Callable[[int, BOResult], None] | None = None,
        prior: dict | None = None,
        prior_mean_fn: Callable | None = None,
        **overrides,
    ):
        cfg = _resolve_search_config(config, overrides)
        self.space = space
        self.cfg = cfg
        self.noisy = noisy
        self.seed = seed
        self.gp_refit_every = gp_refit_every
        self.gp_rank1 = gp_rank1
        self.callback = callback
        self.elite_k = getattr(cfg, "elite_k", 0)
        self.rng = np.random.default_rng(seed)
        self._acq = make_acquisition(cfg.acquisition, cfg.lam)
        self._acq_dev = None

        # Candidate carry-forward (cfg.elite_k): the previous scored trial's
        # top candidates that were NOT evaluated survive into the next
        # trial's pool, so the acquisition optimizer has memory across pool
        # resamples.  Only list pools support appending (the hardware space;
        # packed MappingBatch pools of the software loop keep elite_k = 0).
        self._elites: list = []
        self._observed: set = set()
        # Frozen refit windows: see the comment at `plan`.
        self._can_freeze = gp_refit_every > 1 and bool(
            getattr(space, "supports_pool_freeze", False))

        self._X_feas: list[np.ndarray] = []
        self._y_feas: list[float] = []
        self._X_all: list[np.ndarray] = []
        self._feas_all: list[bool] = []
        # Residual prior mean (cross-run transfer): when `prior_mean_fn` is
        # set the surrogate is fit on y - m(x) and `plan()` adds m back via
        # `apply_prior_mean`, so `_m_feas` mirrors `_y_feas` row-for-row with
        # the m value of each feasible observation.
        self._prior_mean_fn = prior_mean_fn
        self._m_feas: list[float] = []
        self.n_prior = 0
        self.result = BOResult(None, -np.inf, [], [], [])

        self._use_batch = bool(getattr(space, "supports_batch", False))
        # Device-resident scoring needs the GP surrogate (the tree surrogate
        # is host-only) and a space whose feature arrays live on device.
        self._use_device = (
            self._use_batch
            and bool(getattr(space, "supports_device", False))
            and cfg.surrogate in ("gp_linear", "gp_se")
        )
        if prior_mean_fn is not None and self._use_device:
            raise ValueError(
                "prior_mean_fn is host-path only: the fused device scoring "
                "path never materializes host posterior means to offset")
        if prior is not None:
            self._load_prior(prior)

        self._model = None
        self._classifier = None
        self._window_pool = None
        self._window_feats = None
        # Fit boundary bookkeeping for snapshot/restore: the trial index and
        # history lengths of the most recent refit (restore refits from
        # exactly this prefix, then replays any rank-1 appends).
        self._fit: dict | None = None
        self._warmed = min(cfg.n_warmup, cfg.n_trials) == 0
        self._plan: dict | None = None

    # --- state queries -----------------------------------------------------------

    @property
    def done(self) -> bool:
        return self._warmed and len(self.result.history) >= self.cfg.n_trials

    # --- prior observations (cross-run transfer) ---------------------------------

    def _load_prior(self, prior: dict) -> None:
        """Seed the surrogate/classifier data lists with prior observations
        (cross-run transfer) before the first warmup probe.

        `prior` carries feature-space rows only -- no candidate points -- so
        priors shape the *surrogate* (and the feasibility classifier) without
        entering `result`: the incumbent, histories, and trial budget all
        still come exclusively from this run's own evaluations.  Required
        keys: "X_feas" (feasible feature rows), "y_feas" (their utilities),
        "X_all" (every prior row), "feas_all" (their feasibility flags).
        When `prior_mean_fn` is set, "m_feas" (the prior mean at each
        feasible row) is required too -- feature rows cannot be pushed back
        through a point-wise mean function.  An all-empty prior is exactly
        equivalent to no prior."""
        required = ("X_feas", "y_feas", "X_all", "feas_all")
        missing = [k for k in required if k not in prior]
        if missing:
            raise ValueError(f"prior is missing keys {missing}; "
                             f"required: {list(required)}")
        X_feas = [np.asarray(x, dtype=np.float64) for x in prior["X_feas"]]
        y_feas = [float(v) for v in prior["y_feas"]]
        X_all = [np.asarray(x, dtype=np.float64) for x in prior["X_all"]]
        feas_all = [bool(f) for f in prior["feas_all"]]
        if len(X_feas) != len(y_feas):
            raise ValueError(
                f"prior X_feas/y_feas length mismatch: "
                f"{len(X_feas)} != {len(y_feas)}")
        if len(X_all) != len(feas_all):
            raise ValueError(
                f"prior X_all/feas_all length mismatch: "
                f"{len(X_all)} != {len(feas_all)}")
        if len(X_feas) != sum(feas_all):
            raise ValueError(
                f"prior feasible-row count mismatch: {len(X_feas)} X_feas "
                f"rows but {sum(feas_all)} feasible flags in feas_all")
        dim = getattr(self.space, "feature_dim", None)
        for row in X_feas + X_all:
            if row.ndim != 1 or (dim is not None and row.shape != (dim,)):
                raise ValueError(
                    f"prior feature row has shape {row.shape}; expected a "
                    f"1-d row{f' of dim {dim}' if dim is not None else ''}")
        if self._prior_mean_fn is not None:
            if "m_feas" not in prior:
                raise ValueError(
                    "prior_mean_fn is set but prior has no 'm_feas': prior "
                    "mean values cannot be recovered from feature rows")
            m_feas = [float(v) for v in prior["m_feas"]]
            if len(m_feas) != len(X_feas):
                raise ValueError(
                    f"prior m_feas/X_feas length mismatch: "
                    f"{len(m_feas)} != {len(X_feas)}")
            self._m_feas.extend(m_feas)
        self._X_feas.extend(X_feas)
        self._y_feas.extend(y_feas)
        self._X_all.extend(X_all)
        self._feas_all.extend(feas_all)
        self.n_prior = len(X_all)

    # --- inner helpers (the historical closures, verbatim) -----------------------

    def _observe(self, point, feats=None, outcome=None) -> None:
        space, result = self.space, self.result
        feats = space.features(point) if feats is None else feats
        value, feasible = space.evaluate(point) if outcome is None else outcome
        if self.elite_k or self._can_freeze:
            # evaluated points never re-enter as elites, and frozen window
            # pools mask them out
            self._observed.add(point)
        self._X_all.append(feats)
        self._feas_all.append(feasible)
        result.points.append(point)
        if feasible:
            self._X_feas.append(feats)
            self._y_feas.append(value)
            if self._prior_mean_fn is not None:
                self._m_feas.append(
                    float(np.asarray(self._prior_mean_fn([point]))[0]))
            if value > result.best_value:
                result.best_value, result.best_point = value, point
            result.values.append(value)
        else:
            result.n_infeasible += 1
            result.values.append(-np.inf)
        result.history.append(result.best_value)

    def _rank1_update(self, feat_row) -> None:
        """`gp_rank1`: fold the observation just recorded into the surrogate's
        posterior by an O(n^2) incremental Cholesky update (frozen
        hyperparameters; see `GP.append_observation`) instead of leaving the
        posterior stale until the next aligned refit.  GP surrogates only --
        the tree surrogate has no incremental form -- and only feasible
        observations (infeasible ones never enter the objective GP's data)."""
        if not (self.gp_rank1 and isinstance(self._model, GP)):
            return
        v = self.result.values[-1]
        if np.isfinite(v):
            if self._prior_mean_fn is not None:
                v = v - self._m_feas[-1]  # the GP holds residuals y - m(x)
            self._model.append_observation(np.asarray(feat_row, np.float64), v)

    def _update_elites(self, pool, utility, i_best) -> None:
        elite_k, observed = self.elite_k, self._observed
        if not (elite_k and isinstance(pool, list)):
            return
        new: list = []
        winner = pool[i_best]
        for i in score_topk(utility, elite_k + 1 + len(observed)):
            p = pool[int(i)]
            # compare by value, not index: a duplicate of the just-evaluated
            # winner elsewhere in the pool must not survive as an elite
            if p == winner or p in observed or p in new:
                continue
            new.append(p)
            if len(new) == elite_k:
                break
        self._elites[:] = new

    def _sample_valid(self, max_attempts: int = 20_000):
        """Rejection sampling against the *known* input constraints (paper
        §3.4): invalid draws are rejected before any evaluation."""
        for _ in range(max_attempts):
            p = self.space.sample(self.rng)
            if self.space.is_valid(p):
                return p
        raise InfeasibleSpace(getattr(self.space, "name", "space"))

    def _sample_valid_pool(self, n):
        """Input-valid candidate pool as a packed batch (batched protocol)."""
        pool = self.space.sample_pool(self.rng, n)
        if pool is None:
            raise InfeasibleSpace(getattr(self.space, "name", "space"))
        return pool

    def _maybe_refit(self, t: int) -> None:
        surrogate = self.cfg.surrogate
        if not (len(self._y_feas) >= 2
                and (self._model is None or t % self.gp_refit_every == 0)):
            return
        Xf = np.stack(self._X_feas)
        yf = np.asarray(self._y_feas)
        if self._prior_mean_fn is not None:
            yf = yf - np.asarray(self._m_feas)  # fit residuals y - m(x)
        if surrogate == "gp_linear":
            self._model = GP(kind="linear", noisy=self.noisy).fit(Xf, yf)
        elif surrogate == "gp_se":
            self._model = GP(kind="se", noisy=self.noisy).fit(Xf, yf)
        elif surrogate == "rf":
            self._model = RandomForestSurrogate(seed=self.seed + t).fit(Xf, yf)
        else:
            raise ValueError(surrogate)
        if any(not f for f in self._feas_all):
            self._classifier = GPClassifier().fit(
                np.stack(self._X_all), np.asarray(self._feas_all))
        else:
            self._classifier = None
        self._window_pool = self._window_feats = None  # new posterior -> new pool
        self._fit = {"t": t, "n_feas": len(self._y_feas),
                     "n_all": len(self._X_all),
                     "had_clf": self._classifier is not None}

    # --- plan / commit -----------------------------------------------------------

    def plan(self) -> dict | None:
        """Advance to the next evaluation boundary and describe it; None when
        the loop is done.  Plan kinds:

          {"kind": "warmup", "pool": candidates}  the warmup block (evaluated
              in one batch at commit)
          {"kind": "sample", "t", "point"}        a pre-surrogate trial (not
              enough feasible data yet): one random candidate
          {"kind": "scored", "t", "pool", "utility", "k_cap", ...}  a scored
              trial: the acquisition-ranked pool; commit evaluates
              `pool[argmax(utility)]`

        All RNG consumption and surrogate refits happen here; the pending
        plan is cached until `commit()` consumes it, so external schedulers
        may inspect it (and pre-fill evaluation caches) without perturbing
        the trajectory."""
        if self._plan is not None:
            return self._plan
        if self.done:
            return None
        if not self._warmed:
            n_warm = min(self.cfg.n_warmup, self.cfg.n_trials)
            if self._use_batch:
                pool = self._sample_valid_pool(n_warm)
            else:
                pool = [self._sample_valid() for _ in range(n_warm)]
            self._plan = {"kind": "warmup", "pool": pool}
            return self._plan

        t = len(self.result.history)
        self._maybe_refit(t)

        if self._model is None:  # not enough feasible data yet -> keep sampling
            point = (self._sample_valid_pool(1)[0] if self._use_batch
                     else self._sample_valid())
            self._plan = {"kind": "sample", "t": t, "point": point}
            return self._plan

        if self._use_device:
            # Fused pool scoring: features, GP posterior, acquisition, and
            # P(feasible) chain on-device; one scalar index comes back (at
            # commit).
            if self._acq_dev is None:
                self._acq_dev = make_acquisition_device(
                    self.cfg.acquisition, self.cfg.lam)
            pool = self._sample_valid_pool(self.cfg.pool_size)
            feats_dev = self.space.features_batch_device(pool)
            mu, var = self._model.posterior_device(feats_dev)
            utility = self._acq_dev(mu, var, self.result.best_value)
            if self._classifier is not None:
                utility = utility * self._classifier.prob_feasible_device(
                    feats_dev)
            self._plan = {"kind": "scored", "t": t, "pool": pool,
                          "feats": None, "feats_dev": feats_dev,
                          "utility": utility, "k_cap": None, "device": True}
            return self._plan

        # Pool freezing (gp_refit_every > 1 on spaces that opt in through
        # `supports_pool_freeze`, e.g. the hardware space): within one refit
        # window the posterior is fixed, so the window IS one batched
        # acquisition round -- the pool sampled at the refit trial is reused
        # (frozen) by the window's remaining trials with consumed candidates
        # masked out, making the window consume the posterior's top
        # candidates one per trial (the q-batch semantics of BoTorch/
        # Vizier-style parallel suggestion, and what makes speculative
        # prefetches exact for rank-stable acquisitions like LCB).  Spaces
        # without the opt-in (all software spaces; `bo_maximize_many`'s
        # lockstep contract covers them) keep per-trial resampling, and only
        # list pools -- hashable candidate identity -- can freeze.
        frozen = self._window_pool is not None
        if frozen and all(p in self._observed for p in self._window_pool):
            # The window outlived its pool (stride > unobserved candidates):
            # resample instead of re-evaluating masked-out points forever.
            self._window_pool = self._window_feats = None
            frozen = False
        if frozen:
            pool, feats = self._window_pool, self._window_feats
        elif self._use_batch:
            pool = self._sample_valid_pool(self.cfg.pool_size)
            feats = self.space.features_batch(pool)
            if self._elites and isinstance(pool, list):
                # Reuse the base pool's packed features (memoized per pool
                # identity by the space) and append the handful of elite rows
                # scalar-wise -- same column math, so the stacked matrix is
                # bit-identical to featurizing pool + elites from scratch.
                pool = pool + self._elites
                feats = np.vstack(
                    [feats] + [self.space.features(p)[None]
                               for p in self._elites])
        else:
            pool = [self._sample_valid() for _ in range(self.cfg.pool_size)]
            if self._elites:
                pool = pool + self._elites
            feats = np.stack([self.space.features(p) for p in pool])
        if self._can_freeze and not frozen and isinstance(pool, list):
            self._window_pool, self._window_feats = pool, feats
        mu, var = self._model.posterior(feats)
        if self._prior_mean_fn is not None:
            # The surrogate holds residuals y - m(x); put m back before the
            # acquisition so utilities compare against the true incumbent.
            mu = apply_prior_mean(mu, self._prior_mean_fn(pool))
        utility = self._acq(mu, var, self.result.best_value)
        if self._classifier is not None:
            # prob_feasible returns a host array; the asarray keeps the
            # boundary explicit so the acquisition math never silently
            # promotes to device arrays.
            utility = utility * np.asarray(
                self._classifier.prob_feasible(feats))
        if frozen:
            # Already-consumed candidates leave the frozen window pool.
            utility = np.where([p in self._observed for p in pool],
                               -np.inf, utility)
        k_cap = None
        if self._window_pool is not None:
            # Windowed mode: only the window's remaining trials (this one
            # included) can consume a speculated candidate -- wider
            # speculation is guaranteed waste.
            next_refit = (t // self.gp_refit_every + 1) * self.gp_refit_every
            k_cap = min(next_refit, self.cfg.n_trials) - t
        self._plan = {"kind": "scored", "t": t, "pool": pool, "feats": feats,
                      "utility": utility, "k_cap": k_cap, "device": False}
        return self._plan

    def commit(self) -> None:
        """Execute the pending plan (see `plan`): evaluate, observe, update
        elites, fire the prefetch hook and callback."""
        plan = self._plan
        assert plan is not None, "commit() without a pending plan()"
        self._plan = None
        if plan["kind"] == "warmup":
            pool = plan["pool"]
            n_warm = len(pool)
            self._warmed = True
            if self._use_batch and n_warm:
                warm_feats = self.space.features_batch(pool)
                warm_vals, warm_feas = self.space.evaluate_batch(pool)
                for i in range(n_warm):
                    self._observe(pool[i], feats=warm_feats[i],
                                  outcome=(warm_vals[i], bool(warm_feas[i])))
            else:
                for p in pool:
                    self._observe(p)
            return
        t = plan["t"]
        if plan["kind"] == "sample":
            self._observe(plan["point"])
            if self.callback:
                self.callback(t, self.result)
            return
        pool, utility = plan["pool"], plan["utility"]
        if plan["device"]:
            import jax.numpy as jnp

            _prefetch_topk(self.space, pool, utility)
            i_best = int(jnp.argmax(utility))
            feat_row = np.asarray(plan["feats_dev"][i_best], dtype=np.float64)
            self._observe(pool[i_best], feats=feat_row)
            self._rank1_update(feat_row)
        else:
            _prefetch_topk(self.space, pool, utility, k_cap=plan["k_cap"])
            i_best = int(np.argmax(utility))
            self._update_elites(pool, utility, i_best)
            self._observe(pool[i_best], feats=plan["feats"][i_best])
            self._rank1_update(plan["feats"][i_best])
        if self.callback:
            self.callback(t, self.result)

    def step(self) -> bool:
        """plan + commit one stage (the warmup block counts as one stage,
        then one trial per call); returns True while the loop has more work."""
        if self.done:
            return False
        self.plan()
        self.commit()
        return not self.done

    def run(self) -> BOResult:
        while self.step():
            pass
        return self.result

    # --- snapshot / restore ------------------------------------------------------

    def snapshot(self) -> dict:
        """Resumable state as a plain (picklable) dict.  Must be taken at an
        evaluation boundary -- no pending plan (its RNG draws are already
        consumed and cannot be replayed)."""
        if self._plan is not None:
            raise RuntimeError(
                "snapshot() with a pending plan: commit() it first")
        r = self.result
        return {
            "rng": self.rng.bit_generator.state,
            "X_feas": [np.array(x) for x in self._X_feas],
            "y_feas": list(self._y_feas),
            "X_all": [np.array(x) for x in self._X_all],
            "feas_all": list(self._feas_all),
            "m_feas": list(self._m_feas),
            "n_prior": self.n_prior,
            "result": {
                "best_point": r.best_point, "best_value": r.best_value,
                "history": list(r.history), "values": list(r.values),
                "points": list(r.points), "n_infeasible": r.n_infeasible,
            },
            "elites": list(self._elites),
            "observed": list(self._observed),
            "window_pool": (None if self._window_pool is None
                            else list(self._window_pool)),
            "window_feats": (None if self._window_feats is None
                             else np.array(self._window_feats)),
            "fit": None if self._fit is None else dict(self._fit),
            "warmed": self._warmed,
        }

    def restore(self, snap: dict) -> "BOLoop":
        """Load a `snapshot()` into this (freshly constructed, same space +
        config) loop.  The surrogate/classifier are refit from the recorded
        fit boundary's data prefix -- fits are deterministic, so the refit
        model matches the snapshotted one -- and rank-1 appends recorded
        after that boundary are replayed."""
        self.rng.bit_generator.state = snap["rng"]
        self._X_feas = [np.array(x) for x in snap["X_feas"]]
        self._y_feas = list(snap["y_feas"])
        self._X_all = [np.array(x) for x in snap["X_all"]]
        self._feas_all = list(snap["feas_all"])
        self._m_feas = list(snap.get("m_feas", []))
        self.n_prior = int(snap.get("n_prior", 0))
        rs = snap["result"]
        self.result = BOResult(
            best_point=rs["best_point"], best_value=rs["best_value"],
            history=list(rs["history"]), values=list(rs["values"]),
            points=list(rs["points"]), n_infeasible=rs["n_infeasible"])
        self._elites = list(snap["elites"])
        self._observed = set(snap["observed"])
        self._window_pool = (None if snap["window_pool"] is None
                             else list(snap["window_pool"]))
        self._window_feats = (None if snap["window_feats"] is None
                              else np.array(snap["window_feats"]))
        self._fit = None if snap["fit"] is None else dict(snap["fit"])
        self._warmed = snap["warmed"]
        self._plan = None
        self._model = self._classifier = None
        if self._fit is not None:
            fit = self._fit
            n = fit["n_feas"]
            Xf = np.stack(self._X_feas[:n])
            yf = np.asarray(self._y_feas[:n])
            if self._prior_mean_fn is not None:
                yf = yf - np.asarray(self._m_feas[:n])
            surrogate = self.cfg.surrogate
            if surrogate == "gp_linear":
                self._model = GP(kind="linear", noisy=self.noisy).fit(Xf, yf)
            elif surrogate == "gp_se":
                self._model = GP(kind="se", noisy=self.noisy).fit(Xf, yf)
            elif surrogate == "rf":
                self._model = RandomForestSurrogate(
                    seed=self.seed + fit["t"]).fit(Xf, yf)
            else:
                raise ValueError(surrogate)
            if fit["had_clf"]:
                self._classifier = GPClassifier().fit(
                    np.stack(self._X_all[:fit["n_all"]]),
                    np.asarray(self._feas_all[:fit["n_all"]]))
            # Feasible observations recorded after the fit boundary were
            # appended through rank-1 updates (only scored trials run once a
            # model exists, and only under gp_rank1): replay them.
            if self.gp_rank1 and isinstance(self._model, GP):
                for i, (row, v) in enumerate(
                        zip(self._X_feas[n:], self._y_feas[n:])):
                    if self._prior_mean_fn is not None:
                        v = v - self._m_feas[n + i]
                    self._model.append_observation(
                        np.asarray(row, np.float64), float(v))
        return self


def bo_maximize(
    space,
    config: SearchConfig | None = None,
    *,
    noisy: bool = False,
    seed: int = 0,
    gp_refit_every: int = 1,
    gp_rank1: bool = False,
    callback: Callable[[int, BOResult], None] | None = None,
    backend: str | None = None,
    **overrides,
) -> BOResult:
    cfg = _resolve_search_config(config, overrides)
    if backend is not None:
        with _backend_override([space], backend):
            return bo_maximize(
                space, cfg, noisy=noisy, seed=seed,
                gp_refit_every=gp_refit_every, gp_rank1=gp_rank1,
                callback=callback,
            )
    return BOLoop(
        space, cfg, noisy=noisy, seed=seed, gp_refit_every=gp_refit_every,
        gp_rank1=gp_rank1, callback=callback,
    ).run()


@dataclasses.dataclass
class _Cohort:
    """One stacked surrogate fit shared by a set of runs: the `GPStack` (and
    the classifier stack for the subset of its runs that have observed
    unknown-constraint violations), plus the absolute run indices in stack
    order.  With `gp_refit_every == 1` there is exactly one live cohort; with
    a larger stride, runs whose surrogate first became fittable off-schedule
    sit in their own cohort until the next aligned refit (mirroring the
    per-run `model is None or t % gp_refit_every == 0` schedule of
    `bo_maximize`)."""

    model: GPStack
    clf: GPClassifierStack | None
    runs: list[int]
    clf_runs: list[int]


def bo_maximize_many(
    spaces,
    config: SearchConfig | None = None,
    *,
    noisy: bool = False,
    seed: int | Sequence[int] = 0,
    gp_refit_every: int = 1,
    callback: Callable[[int, list[BOResult]], None] | None = None,
    backend: str | None = None,
    **overrides,
) -> list[BOResult]:
    """Advance L independent BO runs in lockstep; returns one `BOResult` per
    space, matching ``[bo_maximize(s, ...) for s in spaces]`` run-for-run
    (each run draws from its own RNG stream, exactly as the sequential calls
    would).  `seed` is one shared seed (the layer-batched nested search: all
    per-layer runs of one probe are seeded alike) or a sequence of L per-run
    seeds (the probe-fanout search: runs belonging to different hardware
    probes keep their probes' distinct seeds).

    Per round, the L-fold repeated work becomes one batched program each:
    candidate pools are featurized by a single fused device dispatch when the
    spaces stack (`LayerStackSpace`; per-space batched calls otherwise), the
    per-run surrogates are refit as one batched `GPStack`, and the posterior /
    acquisition / feasibility-classifier scoring runs over the stacked pools
    at once (device-resident end-to-end on the JAX engine).

    A run whose space proves empirically unsampleable finishes early with an
    empty `BOResult` (best_point None) instead of raising `InfeasibleSpace` --
    the other runs continue; this matches how the nested driver treats a
    layer with no feasible mapping.  Tree surrogates and non-batched spaces
    fall back to sequential `bo_maximize` calls.

    `callback`, when given, receives `(trial_index, results_list)` once per
    lockstep round (not per run; on the sequential fallback it fires per
    advancing run, with empty placeholders for runs not yet started)."""
    cfg = _resolve_search_config(config, overrides)
    spaces = list(spaces)
    L = len(spaces)
    if L == 0:
        return []
    seeds = [seed] * L if isinstance(seed, (int, np.integer)) else list(seed)
    if len(seeds) != L:
        raise ValueError(f"seed sequence has {len(seeds)} entries "
                         f"for {L} spaces")
    if backend is not None:
        with _backend_override(spaces, backend):
            return bo_maximize_many(
                spaces, cfg, noisy=noisy, seed=seeds,
                gp_refit_every=gp_refit_every, callback=callback,
            )
    n_trials, n_warmup, pool_size = cfg.n_trials, cfg.n_warmup, cfg.pool_size
    acquisition, lam, surrogate = cfg.acquisition, cfg.lam, cfg.surrogate

    stackable = (
        surrogate in ("gp_linear", "gp_se")
        and all(getattr(s, "supports_batch", False) for s in spaces)
        and L > 1
    )
    if not stackable:
        # Sequential fallback: tree surrogates are host-only (no stacked fit),
        # scalar-protocol spaces have nothing to stack, and a single run gains
        # nothing from lockstep.  Per-run infeasibility still maps to an empty
        # result so both paths have one contract.  The callback keeps its
        # (trial, results_list) shape -- runs advance one after another here,
        # so it fires once per (run, trial) with the completed runs' results,
        # the advancing run's live result, and empty placeholders for runs
        # not yet started.
        out: list[BOResult] = []
        for i, s in enumerate(spaces):
            cb = None
            if callback is not None:
                rest = [BOResult(None, -np.inf, [], [], [])
                        for _ in spaces[i + 1:]]
                cb = lambda t, r, _rest=rest: callback(t, out + [r] + _rest)
            try:
                out.append(bo_maximize(
                    s, cfg, noisy=noisy, seed=seeds[i],
                    gp_refit_every=gp_refit_every, callback=cb))
            except InfeasibleSpace:
                out.append(BOResult(None, -np.inf, [], [], []))
        return out

    from repro.core.swspace import LayerStackSpace

    stack = LayerStackSpace.maybe(spaces)
    use_device = (
        stack is not None
        and stack.supports_device
        and surrogate in ("gp_linear", "gp_se")
    )
    kind = {"gp_linear": "linear", "gp_se": "se"}[surrogate]

    rngs = [np.random.default_rng(s) for s in seeds]
    acq = make_acquisition(acquisition, lam)
    acq_dev = make_acquisition_device(acquisition, lam) if use_device else None

    results = [BOResult(None, -np.inf, [], [], []) for _ in spaces]
    X_feas: list[list[np.ndarray]] = [[] for _ in spaces]
    y_feas: list[list[float]] = [[] for _ in spaces]
    X_all: list[list[np.ndarray]] = [[] for _ in spaces]
    feas_all: list[list[bool]] = [[] for _ in spaces]
    alive = [True] * L
    cohort_of: list[_Cohort | None] = [None] * L

    def kill(k: int) -> None:
        """Early-stop mask: the run's space proved unsampleable -> finish it
        with an empty result (the sequential path's InfeasibleSpace outcome)."""
        alive[k] = False
        results[k] = BOResult(None, -np.inf, [], [], [])

    def observe(k: int, point, feats=None, outcome=None) -> None:
        feats = spaces[k].features(point) if feats is None else feats
        value, feasible = spaces[k].evaluate(point) if outcome is None else outcome
        X_all[k].append(feats)
        feas_all[k].append(feasible)
        r = results[k]
        r.points.append(point)
        if feasible:
            X_feas[k].append(feats)
            y_feas[k].append(value)
            if value > r.best_value:
                r.best_value, r.best_point = value, point
            r.values.append(value)
        else:
            r.n_infeasible += 1
            r.values.append(-np.inf)
        r.history.append(r.best_value)

    # --- warmup: one stacked evaluation over all runs' warmup pools -----------
    n_warm = min(n_warmup, n_trials)
    if n_warm:
        pools = []
        for k in range(L):
            p = spaces[k].sample_pool(rngs[k], n_warm)
            if p is None:
                kill(k)
                p = None
            pools.append(p)
        live = [k for k in range(L) if alive[k]]
        if live:
            if stack is not None:
                full = [p if p is not None else stack.placeholder_pool(n_warm)
                        for p in pools]
                fwd = stack.forward_stacked(full, runs=live)
                feats_w, vals_w, feas_w = (
                    fwd["features"], fwd["utility"], fwd["valid"])
            else:
                d = spaces[0].feature_dim
                feats_w = np.zeros((L, n_warm, d))
                vals_w = np.full((L, n_warm), -np.inf)
                feas_w = np.zeros((L, n_warm), dtype=bool)
                for k in live:
                    feats_w[k] = spaces[k].features_batch(pools[k])
                    vals_w[k], feas_w[k] = spaces[k].evaluate_batch(pools[k])
            for k in live:
                for i in range(n_warm):
                    observe(k, pools[k][i], feats=feats_w[k, i],
                            outcome=(vals_w[k, i], bool(feas_w[k, i])))

    # --- lockstep trials ------------------------------------------------------
    for t in range(n_warm, n_trials):
        if not any(alive):
            break
        # Refit cohort: every run whose surrogate is due this round, fit as
        # ONE batched GPStack (+ one classifier stack for the runs that have
        # seen unknown-constraint violations).
        need = [k for k in range(L)
                if alive[k] and len(y_feas[k]) >= 2
                and (cohort_of[k] is None or t % gp_refit_every == 0)]
        if need:
            gps = GPStack(kind=kind, noisy=noisy).fit(
                [np.stack(X_feas[k]) for k in need],
                [np.asarray(y_feas[k]) for k in need])
            clf_runs = [k for k in need if not all(feas_all[k])]
            clf = (GPClassifierStack().fit(
                       [np.stack(X_all[k]) for k in clf_runs],
                       [np.asarray(feas_all[k]) for k in clf_runs])
                   if clf_runs else None)
            cohort = _Cohort(gps, clf, need, clf_runs)
            for k in need:
                cohort_of[k] = cohort

        # Runs without a surrogate yet keep sampling (scalar, like the
        # sequential path: one candidate, scalar features + evaluation).
        for k in range(L):
            if alive[k] and cohort_of[k] is None:
                p = spaces[k].sample_pool(rngs[k], 1)
                if p is None:
                    kill(k)
                else:
                    observe(k, p[0])

        scoring = [k for k in range(L) if alive[k] and cohort_of[k] is not None]
        if scoring:
            pools = [None] * L
            for k in scoring:
                pools[k] = spaces[k].sample_pool(rngs[k], pool_size)
                if pools[k] is None:
                    kill(k)
            scoring = [k for k in scoring if alive[k]]
        if scoring:
            feats = feats_dev = None
            if stack is not None:
                full = [p if p is not None else stack.placeholder_pool(pool_size)
                        for p in pools]
                if use_device:
                    feats_dev = stack.features_stacked_device(full)
                else:
                    feats = stack.features_stacked(full, runs=scoring)
            else:
                d = spaces[0].feature_dim
                feats = np.zeros((L, pool_size, d))
                for k in scoring:
                    feats[k] = spaces[k].features_batch(pools[k])

            scoring_set = set(scoring)
            cohorts = list({id(cohort_of[k]): cohort_of[k] for k in scoring}.values())
            for cohort in cohorts:
                runs = cohort.runs
                best = np.array([[results[k].best_value] for k in runs])
                if use_device:
                    import jax.numpy as jnp
                    from jax.experimental import enable_x64

                    # The stacked features are f64 device arrays; every op on
                    # them (gathers included) must trace under scoped x64 --
                    # and the incumbents must enter as f64 (like the
                    # sequential path's Python-float best) or EI loses
                    # precision.
                    with enable_x64():
                        sub = feats_dev[jnp.asarray(runs)]
                    if cohort.clf is None:
                        # Hot case (the inner software searches sample
                        # input-valid pools, so no classifier ever fits):
                        # posterior + acquisition + argmax + winner gather
                        # fused into one dispatch.
                        idx, rows = cohort.model.score_device(
                            sub, best, acquisition, lam)
                    else:
                        with enable_x64():
                            mu, var = cohort.model.posterior_device(sub)
                            util = acq_dev(mu, var, jnp.asarray(best))
                            pos = jnp.asarray(
                                [runs.index(k) for k in cohort.clf_runs])
                            probs = cohort.clf.prob_feasible_device(
                                feats_dev[jnp.asarray(cohort.clf_runs)])
                            util = util.at[pos].multiply(probs)
                            idx = np.asarray(jnp.argmax(util, axis=1))
                            rows = np.asarray(
                                jnp.take_along_axis(
                                    sub, jnp.asarray(idx)[:, None, None],
                                    axis=1)[:, 0, :],
                                dtype=np.float64)
                else:
                    sub = feats[np.asarray(runs)]
                    mu, var = cohort.model.posterior(sub)
                    util = acq(mu, var, best)
                    if cohort.clf is not None:
                        pos = [runs.index(k) for k in cohort.clf_runs]
                        util[pos] = util[pos] * np.asarray(
                            cohort.clf.prob_feasible(
                                feats[np.asarray(cohort.clf_runs)]))
                    idx = np.argmax(util, axis=1)
                    rows = sub[np.arange(len(runs)), idx]
                for r, k in enumerate(runs):
                    if k in scoring_set:
                        observe(k, pools[k][int(idx[r])],
                                feats=np.asarray(rows[r], dtype=np.float64))
        if callback:
            callback(t, results)

    return results


@dataclasses.dataclass(frozen=True)
class FanoutSearchSpec:
    """A pickle-safe description of one stacked multi-item inner search.

    This is the unit of work the executor layer (`repro.parallel`) moves
    between processes: exactly the `(hw, layer)` items a
    `SearchSession.pending()` emits, with their content-derived seeds, plus
    the two config sections that determine the search.  `run()` reproduces
    what the learner would have computed inline -- one
    `optimize_software_fanout` stacked dispatch -- and reduces each item's
    `BOResult` to the `(mapping | None, edp)` cache entry, so the IPC payload
    back to the learner is a few floats per item instead of a full history.

    Everything here is a frozen dataclass of plain scalars, so the spec
    crosses a spawn boundary with the default pickler and unpickling it does
    not import any evaluation backend.
    """

    items: tuple          # ((hw, layer), ...) pairs, order-significant
    seeds: tuple          # per-item content-derived seeds, len == len(items)
    sw: SWSearchConfig
    engine: Any           # EngineConfig (typed loosely: config imports no bo)
    pad_to: int | None = None

    def run(self) -> list:
        # Late imports: unpickling a spec must stay cheap, and the module
        # attribute lookup keeps test spies on
        # `nested.optimize_software_fanout` effective under every executor.
        from repro.core import nested

        results = nested.optimize_software_fanout(
            list(self.items), self.sw, seeds=list(self.seeds),
            engine=self.engine, pad_to=self.pad_to)
        return [nested._cache_entry(hw, layer, r)
                for (hw, layer), r in zip(self.items, results)]
