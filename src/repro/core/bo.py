"""Generic constrained Bayesian optimization loop (paper §3, §4).

The loop implements the paper's scheme exactly:
  * warmup with random feasible samples (5 HW / 30 SW in the paper),
  * fit the objective surrogate on feasible observations (linear kernel on
    engineered features; noise kernel only when the evaluator is noisy),
  * if any *output*-infeasible points have been observed, fit the SE-kernel GP
    classifier and weight the acquisition by P(C(x)) (Gelbart et al. 2014),
  * optimize the acquisition by rejection sampling: pool `pool_size` candidates
    that satisfy all input constraints, pick the acquisition argmax,
  * evaluate, record, repeat for `n_trials`.

Spaces may implement the *batched evaluation protocol* — `supports_batch`
(truthy), `sample_pool(rng, n)`, `features_batch(pool)`, `evaluate_batch(pool)`
(see `repro.timeloop.batch`) — in which case warmup draws and the per-trial
acquisition pool are sampled, featurized, and scored as whole arrays instead of
one candidate at a time; spaces without it (e.g. the hardware space, whose
evaluator is a nested search) transparently fall back to the scalar path.

Spaces that additionally expose `supports_device` + `features_batch_device`
(the JAX engine, `repro.timeloop.batch_jax`) get *device-resident* pool
scoring: featurization, GP posterior, acquisition, and the feasibility
classifier all stay on-device as one fused chain per trial, and only the
argmax index (plus the winner's feature row) crosses back to the host.
Everything on the host side of that boundary is kept strictly NumPy —
`np.asarray` at every device edge — so no host computation silently promotes
to device arrays with a blocking transfer per trial.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np

from repro.core.acquisition import make_acquisition, make_acquisition_device
from repro.core.gp import GP, GPClassifier
from repro.core.trees import RandomForestSurrogate


class InfeasibleSpace(RuntimeError):
    """Raised when input-constraint rejection sampling cannot find any valid
    point -- the search space itself is (empirically) empty.  At the hardware
    level this is the paper's *unknown constraint*."""


@dataclasses.dataclass
class BOResult:
    best_point: Any
    best_value: float                 # utility (maximized): -log10(EDP)
    history: list[float]              # best-so-far utility per trial
    values: list[float]               # raw utility per trial (-inf if infeasible)
    points: list[Any]
    n_infeasible: int = 0


def bo_maximize(
    space,
    n_trials: int = 250,
    n_warmup: int = 30,
    pool_size: int = 150,
    acquisition: str = "lcb",
    lam: float = 1.0,
    surrogate: str = "gp_linear",
    noisy: bool = False,
    seed: int = 0,
    gp_refit_every: int = 1,
    callback: Callable[[int, BOResult], None] | None = None,
    backend: str | None = None,
) -> BOResult:
    if backend is not None:
        # Engine override for spaces that carry one, scoped to this run --
        # the caller's space is restored on the way out.  Unknown values and
        # spaces without backend selection are reported, never ignored.
        from repro.core.swspace import BACKENDS

        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
        if not hasattr(space, "backend"):
            raise ValueError(
                f"space {getattr(space, 'name', space)!r} does not support "
                "backend selection")
        prev_backend = space.backend
        space.backend = backend
        try:
            return bo_maximize(
                space, n_trials=n_trials, n_warmup=n_warmup,
                pool_size=pool_size, acquisition=acquisition, lam=lam,
                surrogate=surrogate, noisy=noisy, seed=seed,
                gp_refit_every=gp_refit_every, callback=callback,
            )
        finally:
            space.backend = prev_backend
    rng = np.random.default_rng(seed)
    acq = make_acquisition(acquisition, lam)
    acq_dev = None

    X_feas: list[np.ndarray] = []
    y_feas: list[float] = []
    X_all: list[np.ndarray] = []
    feas_all: list[bool] = []
    result = BOResult(None, -np.inf, [], [], [])

    use_batch = bool(getattr(space, "supports_batch", False))
    # Device-resident scoring needs the GP surrogate (the tree surrogate is
    # host-only) and a space whose feature arrays already live on device.
    use_device = (
        use_batch
        and bool(getattr(space, "supports_device", False))
        and surrogate in ("gp_linear", "gp_se")
    )

    def observe(point, feats=None, outcome=None):
        feats = space.features(point) if feats is None else feats
        value, feasible = space.evaluate(point) if outcome is None else outcome
        X_all.append(feats)
        feas_all.append(feasible)
        result.points.append(point)
        if feasible:
            X_feas.append(feats)
            y_feas.append(value)
            if value > result.best_value:
                result.best_value, result.best_point = value, point
            result.values.append(value)
        else:
            result.n_infeasible += 1
            result.values.append(-np.inf)
        result.history.append(result.best_value)

    def sample_valid(max_attempts: int = 20_000):
        """Rejection sampling against the *known* input constraints (paper §3.4):
        invalid draws are rejected before any evaluation."""
        for _ in range(max_attempts):
            p = space.sample(rng)
            if space.is_valid(p):
                return p
        raise InfeasibleSpace(getattr(space, "name", "space"))

    def sample_valid_pool(n):
        """Input-valid candidate pool as a packed batch (batched protocol)."""
        pool = space.sample_pool(rng, n)
        if pool is None:
            raise InfeasibleSpace(getattr(space, "name", "space"))
        return pool

    # --- warmup ---------------------------------------------------------------
    n_warm = min(n_warmup, n_trials)
    if use_batch and n_warm:
        warm = sample_valid_pool(n_warm)
        warm_feats = space.features_batch(warm)
        warm_vals, warm_feas = space.evaluate_batch(warm)
        for i in range(n_warm):
            observe(warm[i], feats=warm_feats[i],
                    outcome=(warm_vals[i], bool(warm_feas[i])))
    else:
        for _ in range(n_warm):
            observe(sample_valid())

    model = None
    classifier = None
    for t in range(len(result.history), n_trials):
        if len(y_feas) >= 2 and (model is None or t % gp_refit_every == 0):
            Xf = np.stack(X_feas)
            yf = np.asarray(y_feas)
            if surrogate == "gp_linear":
                model = GP(kind="linear", noisy=noisy).fit(Xf, yf)
            elif surrogate == "gp_se":
                model = GP(kind="se", noisy=noisy).fit(Xf, yf)
            elif surrogate == "rf":
                model = RandomForestSurrogate(seed=seed + t).fit(Xf, yf)
            else:
                raise ValueError(surrogate)
            if any(not f for f in feas_all):
                classifier = GPClassifier().fit(np.stack(X_all), np.asarray(feas_all))
            else:
                classifier = None

        if model is None:  # not enough feasible data yet -> keep sampling
            observe(sample_valid_pool(1)[0] if use_batch else sample_valid())
            if callback:
                callback(t, result)
            continue

        if use_device:
            # Fused pool scoring: features, GP posterior, acquisition, and
            # P(feasible) chain on-device; one scalar index comes back.
            import jax.numpy as jnp

            if acq_dev is None:
                acq_dev = make_acquisition_device(acquisition, lam)
            pool = sample_valid_pool(pool_size)
            feats_dev = space.features_batch_device(pool)
            mu, var = model.posterior_device(feats_dev)
            utility = acq_dev(mu, var, result.best_value)
            if classifier is not None:
                utility = utility * classifier.prob_feasible_device(feats_dev)
            i_best = int(jnp.argmax(utility))
            observe(pool[i_best],
                    feats=np.asarray(feats_dev[i_best], dtype=np.float64))
            if callback:
                callback(t, result)
            continue

        if use_batch:
            pool = sample_valid_pool(pool_size)
            feats = space.features_batch(pool)
        else:
            pool = [sample_valid() for _ in range(pool_size)]
            feats = np.stack([space.features(p) for p in pool])
        mu, var = model.posterior(feats)
        utility = acq(mu, var, result.best_value)
        if classifier is not None:
            # prob_feasible returns a host array; the asarray keeps the
            # boundary explicit so the acquisition math never silently
            # promotes to device arrays.
            utility = utility * np.asarray(classifier.prob_feasible(feats))
        i_best = int(np.argmax(utility))
        observe(pool[i_best], feats=feats[i_best])
        if callback:
            callback(t, result)

    return result
