"""Exact Gaussian processes in JAX (paper §3.2).

Kernels: squared-exponential (ARD optional), linear-on-features, and an additive
noise kernel.  Hyperparameters live in log space and are fit by full-batch Adam
on the negative marginal log-likelihood.  Dataset sizes here are tiny (<= a few
hundred), so exact Cholesky GPs are cheap; to keep the jitted fit fast on CPU we
pad X/y to bucketed sizes (powers of two) with masked-out rows so the compiled
function is reused across BO iterations.

The Cholesky solves need float64, but that is scoped to the GP computations via
the `jax.experimental.enable_x64` context -- importing this module does NOT flip
the process-global x64 flag (which would silently force every other JAX program
in the process, e.g. the float32 Pallas evaluation engine, to f64).  The fitted
state is held as f64 device arrays, which flow through jit fine regardless of
the global flag.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64
from scipy.special import erf as _erf

_JITTER = 1e-6
_PAD_NOISE = 1e6  # effective infinite noise on padded rows -> zero influence


def _bucket(n: int) -> int:
    b = 8
    while b < n:
        b *= 2
    return b


def se_kernel(params, x1, x2):
    """Squared exponential with scalar lengthscale (paper's constraint GP)."""
    alpha = jnp.exp(params["log_alpha"])
    ell = jnp.exp(params["log_ell"])
    d2 = jnp.sum((x1[:, None, :] - x2[None, :, :]) ** 2, axis=-1)
    return alpha**2 * jnp.exp(-d2 / (ell**2))


def linear_kernel(params, x1, x2):
    """Linear kernel on explicit features with learned per-feature scales
    (paper §3.2: "a linear kernel on top of explicit features")."""
    w = jnp.exp(params["log_w"])
    return (x1 * w) @ (x2 * w).T + jnp.exp(params["log_bias"]) ** 2


KERNELS = {"se": se_kernel, "linear": linear_kernel}


def _init_params(kind: str, dim: int) -> dict:
    if kind == "se":
        return {"log_alpha": jnp.zeros(()), "log_ell": jnp.zeros(())}
    if kind == "linear":
        return {"log_w": jnp.zeros((dim,)), "log_bias": jnp.zeros(())}
    raise ValueError(kind)


@functools.partial(jax.jit, static_argnames=("kind",))
def _nll(params, X, y, mask, kind):
    k = KERNELS[kind]
    n = X.shape[0]
    noise = jnp.exp(2.0 * params["log_tau"])
    diag = jnp.where(mask > 0.5, noise + _JITTER, _PAD_NOISE)
    K = k(params, X, X) * (mask[:, None] * mask[None, :]) + jnp.diag(diag)
    c = params["mean_const"]
    r = jnp.where(mask > 0.5, y - c, 0.0)
    L = jnp.linalg.cholesky(K)
    alpha = jax.scipy.linalg.cho_solve((L, True), r)
    quad = r @ alpha
    logdet = 2.0 * jnp.sum(jnp.where(mask > 0.5, jnp.log(jnp.diagonal(L)), 0.0))
    n_eff = jnp.sum(mask)
    return 0.5 * (quad + logdet + n_eff * jnp.log(2.0 * jnp.pi))


@functools.partial(jax.jit, static_argnames=("kind", "steps", "lr", "train_tau"))
def _fit(params, X, y, mask, kind, steps=80, lr=0.05, train_tau=True):
    grad_fn = jax.grad(_nll)

    def adam_step(carry, _):
        p, m, v, t = carry
        g = grad_fn(p, X, y, mask, kind)
        if not train_tau:
            # Deterministic evaluator: the noise level is pinned, so exclude it
            # from the update entirely -- otherwise the other hyperparameters
            # are optimized against a drifting noise level that is only
            # re-pinned after the fact.
            g = dict(g, log_tau=jnp.zeros_like(g["log_tau"]))
        t = t + 1
        m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v = jax.tree.map(lambda a, b: 0.999 * a + 0.001 * b * b, v, g)
        mh = jax.tree.map(lambda a: a / (1 - 0.9**t), m)
        vh = jax.tree.map(lambda a: a / (1 - 0.999**t), v)
        p = jax.tree.map(lambda a, b, c: a - lr * b / (jnp.sqrt(c) + 1e-8), p, mh, vh)
        return (p, m, v, t), None

    zeros = jax.tree.map(jnp.zeros_like, params)
    (params, _, _, _), _ = jax.lax.scan(
        adam_step, (params, zeros, zeros, 0.0), None, length=steps
    )
    return params


@functools.partial(jax.jit, static_argnames=("kind",))
def _posterior(params, X, y, mask, Xs, kind):
    k = KERNELS[kind]
    noise = jnp.exp(2.0 * params["log_tau"])
    diag = jnp.where(mask > 0.5, noise + _JITTER, _PAD_NOISE)
    K = k(params, X, X) * (mask[:, None] * mask[None, :]) + jnp.diag(diag)
    c = params["mean_const"]
    r = jnp.where(mask > 0.5, y - c, 0.0)
    L = jnp.linalg.cholesky(K)
    alpha = jax.scipy.linalg.cho_solve((L, True), r)
    Ks = k(params, Xs, X) * mask[None, :]
    mu = Ks @ alpha + c
    v = jax.scipy.linalg.solve_triangular(L, Ks.T, lower=True)
    kss = jax.vmap(lambda x: k(params, x[None], x[None])[0, 0])(Xs)
    var = jnp.maximum(kss - jnp.sum(v**2, axis=0), 1e-10)
    return mu, var


@dataclasses.dataclass
class GP:
    """Exact GP regressor.

    kind:        'se' or 'linear'
    noisy:       if False, the noise is pinned tiny (deterministic evaluator,
                 paper §4.3); if True it is a learned hyperparameter (paper §4.2).
    """

    kind: str = "linear"
    noisy: bool = True
    steps: int = 80
    _state: tuple | None = None

    def fit(self, X: np.ndarray, y: np.ndarray) -> "GP":
        X = np.asarray(X, np.float64)
        y = np.asarray(y, np.float64)
        n, d = X.shape
        b = _bucket(n)
        Xp = np.zeros((b, d))
        yp = np.zeros((b,))
        mask = np.zeros((b,))
        Xp[:n], yp[:n], mask[:n] = X, y, 1.0
        with enable_x64():
            params = _init_params(self.kind, d)
            params["mean_const"] = jnp.asarray(float(y.mean()))
            params["log_tau"] = jnp.asarray(
                np.log(max(y.std(), 1e-3) * 0.1) if self.noisy else -6.0)
            # With noisy=False the pinned log_tau is frozen *during* the fit
            # (zeroed gradient), so the remaining hyperparameters are trained
            # against the true fixed noise level -- no post-fit re-pin needed.
            params = _fit(params, jnp.asarray(Xp), jnp.asarray(yp),
                          jnp.asarray(mask), self.kind, self.steps,
                          train_tau=self.noisy)
            self._state = (params, jnp.asarray(Xp), jnp.asarray(yp),
                           jnp.asarray(mask))
        return self

    def posterior(self, Xs: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        mu, var = self.posterior_device(Xs)
        return np.asarray(mu), np.asarray(var)

    def posterior_device(self, Xs) -> tuple[jax.Array, jax.Array]:
        """Posterior as device arrays -- lets the batched-engine acquisition
        scoring stay device-resident (no host round-trip per BO trial)."""
        assert self._state is not None, "fit() first"
        params, Xp, yp, mask = self._state
        with enable_x64():
            Xs = jnp.asarray(Xs, jnp.float64)
            return _posterior(params, Xp, yp, mask, Xs, self.kind)

    @property
    def params(self):
        return self._state[0] if self._state else None


@dataclasses.dataclass
class GPClassifier:
    """GP "classifier" for unknown (output) constraints (paper §3.4): GP
    regression on +/-1 labels with a probit link on the latent posterior --
    the standard cheap approximation used in constrained BO."""

    steps: int = 80
    _gp: GP | None = None

    def fit(self, X: np.ndarray, feasible: np.ndarray) -> "GPClassifier":
        y = np.where(np.asarray(feasible), 1.0, -1.0)
        self._gp = GP(kind="se", noisy=True, steps=self.steps).fit(X, y)
        return self

    def prob_feasible(self, Xs: np.ndarray) -> np.ndarray:
        """Host-side P(feasible): returns a plain NumPy array.  (The erf runs
        on the host -- a JAX array here would silently promote the whole
        acquisition computation in `bo_maximize` to device arrays with a
        blocking transfer per trial.)"""
        if self._gp is None:
            return np.ones(len(Xs))
        mu, var = self._gp.posterior(Xs)
        z = mu / np.sqrt(1.0 + var)
        return 0.5 * (1.0 + _erf(z / np.sqrt(2.0)))

    def prob_feasible_device(self, Xs) -> jax.Array:
        """Device-resident twin of `prob_feasible` for the fused scoring path.
        (The erf must trace under scoped x64, or its internal constants
        canonicalize to f32 and poison the f64 posterior's precision.  Even
        then jax's and scipy's erf differ by ~1e-8 -- implementation, not
        dtype -- so host/device probabilities agree to ~1e-8, far below
        anything the acquisition argmax can resolve.)"""
        if self._gp is None:
            return jnp.ones(len(Xs))
        mu, var = self._gp.posterior_device(Xs)
        with enable_x64():
            z = mu / jnp.sqrt(1.0 + var)
            return 0.5 * (1.0 + jax.scipy.special.erf(z / np.sqrt(2.0)))
