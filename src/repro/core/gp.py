"""Exact Gaussian processes in JAX (paper §3.2).

Kernels: squared-exponential (ARD optional), linear-on-features, and an additive
noise kernel.  Hyperparameters live in log space and are fit by full-batch Adam
on the negative marginal log-likelihood.  Dataset sizes here are tiny (<= a few
hundred), so exact Cholesky GPs are cheap; to keep the jitted fit fast on CPU we
pad X/y to bucketed sizes (powers of two) with masked-out rows so the compiled
function is reused across BO iterations.

`GPStack` / `GPClassifierStack` fit and query L *independent* GPs as one
batched program (`lax.map` over the leading run axis: batched Cholesky
solves for the fit, one device posterior over the stacked candidate pools).
The layer-batched nested search uses this to replace L sequential per-layer
surrogate refits -- the end-to-end bottleneck once the evaluation engine is
vectorized -- with a single batched fit per BO round.  Padding is *exactly*
zero-influence (masked kernel rows make the padded block of the Cholesky
factor decouple: alpha is exactly 0 on padded rows, and the NLL masks their
logdet terms), so each slice of a stack reproduces the corresponding
individual `GP` fit regardless of how runs are padded to the shared bucket.

The Cholesky solves need float64, but that is scoped to the GP computations via
the `jax.experimental.enable_x64` context -- importing this module does NOT flip
the process-global x64 flag (which would silently force every other JAX program
in the process, e.g. the float32 Pallas evaluation engine, to f64).  The fitted
state is held as f64 device arrays, which flow through jit fine regardless of
the global flag.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64
from scipy.special import erf as _erf

_JITTER = 1e-6
_PAD_NOISE = 1e6  # effective infinite noise on padded rows -> zero influence
# Stacked linear-kernel fits switch to the O(n d^2) Woodbury NLL above this
# many (padded) data rows; below it the O(n^3) Cholesky NLL is cheap and keeps
# the stacked fit bit-identical to the sequential one (see `_fit_stack`).
_LOWRANK_MIN_ROWS = 32


def _bucket(n: int) -> int:
    b = 8
    while b < n:
        b *= 2
    return b


def se_kernel(params, x1, x2):
    """Squared exponential with scalar lengthscale (paper's constraint GP)."""
    alpha = jnp.exp(params["log_alpha"])
    ell = jnp.exp(params["log_ell"])
    d2 = jnp.sum((x1[:, None, :] - x2[None, :, :]) ** 2, axis=-1)
    return alpha**2 * jnp.exp(-d2 / (ell**2))


def linear_kernel(params, x1, x2):
    """Linear kernel on explicit features with learned per-feature scales
    (paper §3.2: "a linear kernel on top of explicit features")."""
    w = jnp.exp(params["log_w"])
    return (x1 * w) @ (x2 * w).T + jnp.exp(params["log_bias"]) ** 2


KERNELS = {"se": se_kernel, "linear": linear_kernel}


def _init_params(kind: str, dim: int) -> dict:
    if kind == "se":
        return {"log_alpha": jnp.zeros(()), "log_ell": jnp.zeros(())}
    if kind == "linear":
        return {"log_w": jnp.zeros((dim,)), "log_bias": jnp.zeros(())}
    raise ValueError(kind)


def _nll_linear_lowrank(params, X, y, mask):
    """`_nll(kind="linear")` via Woodbury -- same value, O(n d^2) not O(n^3).

    The linear kernel is rank d+1: K = (M V0)(M V0)^T + bias^2 (M 1)(M 1)^T
    + D with V0 = X * w, M = diag(mask), D the masked noise/pad diagonal.
    With V = M [V0, bias 1] (n, d+1) and A = I + V^T D^-1 V:

      quad            r^T K^-1 r = r^T D^-1 r - u^T A^-1 u,  u = V^T D^-1 r
      masked logdet   sum_masked log D_ii + logdet A

    (pad rows have V = 0 and r = 0, so they drop out of both terms exactly,
    matching the masked Cholesky logdet of `_nll`).  Used by the stacked
    multi-run fit, where the surrogate refit is the dominant per-trial cost;
    agrees with `_nll` to f64 roundoff (~1e-12 relative), parity-tested."""
    n = X.shape[0]
    noise = jnp.exp(2.0 * params["log_tau"])
    diag = jnp.where(mask > 0.5, noise + _JITTER, _PAD_NOISE)
    w = jnp.exp(params["log_w"])
    V = jnp.concatenate(
        [X * w, jnp.full((n, 1), jnp.exp(params["log_bias"]))], axis=1)
    V = V * mask[:, None]
    r = jnp.where(mask > 0.5, y - params["mean_const"], 0.0)
    Vd = V / diag[:, None]
    A = jnp.eye(V.shape[1], dtype=X.dtype) + V.T @ Vd
    La = jnp.linalg.cholesky(A)
    u = Vd.T @ r
    quad = r @ (r / diag) - u @ jax.scipy.linalg.cho_solve((La, True), u)
    logdet = (jnp.sum(jnp.where(mask > 0.5, jnp.log(diag), 0.0))
              + 2.0 * jnp.sum(jnp.log(jnp.diagonal(La))))
    n_eff = jnp.sum(mask)
    return 0.5 * (quad + logdet + n_eff * jnp.log(2.0 * jnp.pi))


@functools.partial(jax.jit, static_argnames=("kind",))
def _nll(params, X, y, mask, kind):
    k = KERNELS[kind]
    n = X.shape[0]
    noise = jnp.exp(2.0 * params["log_tau"])
    diag = jnp.where(mask > 0.5, noise + _JITTER, _PAD_NOISE)
    K = k(params, X, X) * (mask[:, None] * mask[None, :]) + jnp.diag(diag)
    c = params["mean_const"]
    r = jnp.where(mask > 0.5, y - c, 0.0)
    L = jnp.linalg.cholesky(K)
    alpha = jax.scipy.linalg.cho_solve((L, True), r)
    quad = r @ alpha
    logdet = 2.0 * jnp.sum(jnp.where(mask > 0.5, jnp.log(jnp.diagonal(L)), 0.0))
    n_eff = jnp.sum(mask)
    return 0.5 * (quad + logdet + n_eff * jnp.log(2.0 * jnp.pi))


@functools.partial(jax.jit,
                   static_argnames=("kind", "steps", "lr", "train_tau",
                                    "lowrank", "tol"))
def _fit(params, X, y, mask, kind, steps=80, lr=0.05, train_tau=True,
         lowrank=False, tol=0.0):
    # lowrank: optimize the Woodbury form of the linear-kernel NLL (same
    # function to f64 roundoff, O(n d^2) per step) -- the stacked multi-run
    # fit uses it; the single-run path keeps the Cholesky NLL.
    if lowrank:
        assert kind == "linear", "lowrank NLL exists for the linear kernel"
        grad_fn = jax.grad(
            lambda p, xx, yy, mm, _k: _nll_linear_lowrank(p, xx, yy, mm))
    else:
        grad_fn = jax.grad(_nll)

    def adam_update(carry, g):
        p, m, v, t = carry
        if not train_tau:
            # Deterministic evaluator: the noise level is pinned, so exclude it
            # from the update entirely -- otherwise the other hyperparameters
            # are optimized against a drifting noise level that is only
            # re-pinned after the fact.
            g = dict(g, log_tau=jnp.zeros_like(g["log_tau"]))
        t = t + 1
        m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v = jax.tree.map(lambda a, b: 0.999 * a + 0.001 * b * b, v, g)
        mh = jax.tree.map(lambda a: a / (1 - 0.9**t), m)
        vh = jax.tree.map(lambda a: a / (1 - 0.999**t), v)
        p = jax.tree.map(lambda a, b, c: a - lr * b / (jnp.sqrt(c) + 1e-8), p, mh, vh)
        return p, m, v, t

    zeros = jax.tree.map(jnp.zeros_like, params)
    if tol == 0.0:
        # Fixed-length scan: the default path, byte-for-byte the pre-tol fit.
        def adam_step(carry, _):
            g = grad_fn(carry[0], X, y, mask, kind)
            return adam_update(carry, g), None

        (params, _, _, _), _ = jax.lax.scan(
            adam_step, (params, zeros, zeros, 0.0), None, length=steps
        )
        return params

    # Gradient-norm early-exit (tolerance-gated): identical Adam updates, but
    # the loop stops once the global gradient norm of the step just applied
    # drops below `tol` -- converged fits skip the remaining steps instead of
    # always burning all `steps` of them.
    def cond(carry):
        _, _, _, t, gn = carry
        return (t < steps) & (gn >= tol)

    def body(carry):
        p, m, v, t, _ = carry
        g = grad_fn(p, X, y, mask, kind)
        if not train_tau:
            g = dict(g, log_tau=jnp.zeros_like(g["log_tau"]))
        gn = jnp.sqrt(sum(jnp.sum(leaf ** 2) for leaf in jax.tree.leaves(g)))
        p, m, v, t = adam_update((p, m, v, t), g)
        return p, m, v, t, gn

    params, _, _, _, _ = jax.lax.while_loop(
        cond, body, (params, zeros, zeros, 0.0, jnp.asarray(jnp.inf, X.dtype)))
    return params


# --- incremental (rank-1) posterior updates --------------------------------------
#
# Between aligned refits the BO loop's surrogate hyperparameters are frozen, so
# appending one observation only changes the DATA side of the posterior: the
# padded kernel matrix gains one real row/column in the first padded slot.
# Because padded rows are exactly decoupled (zero off-diagonal, _PAD_NOISE
# diagonal -- see module docstring), the Cholesky factor of the updated matrix
# differs from the cached one in exactly that row: a standard border update
# L[n, :n] = L^-1 k_new, L[n, n] = sqrt(k(x,x) + noise + jitter - |L[n,:n]|^2),
# computed in O(n^2) instead of the O(n^3) refactorization `_posterior` does
# per call.  Posterior queries then reuse the cached factor (`_posterior_chol`)
# -- the same downstream solves as `_posterior`, parity-pinned to <= 1e-8 in
# tests/test_gp_rank1.py against a frozen-hyperparameter refit from scratch.

@functools.partial(jax.jit, static_argnames=("kind",))
def _chol_factor(params, X, mask, kind):
    """Cholesky factor of the masked padded kernel matrix (the same K that
    `_nll` / `_posterior` build internally)."""
    k = KERNELS[kind]
    noise = jnp.exp(2.0 * params["log_tau"])
    diag = jnp.where(mask > 0.5, noise + _JITTER, _PAD_NOISE)
    K = k(params, X, X) * (mask[:, None] * mask[None, :]) + jnp.diag(diag)
    return jnp.linalg.cholesky(K)


@functools.partial(jax.jit, static_argnames=("kind",))
def _append_row(params, L, X, y, mask, x, val, kind):
    """Rank-1 border update: append one observation into the first padded
    slot, updating the cached factor in O(n^2).  Returns (L, X, y, mask)."""
    k = KERNELS[kind]
    n = jnp.sum(mask).astype(jnp.int32)  # first padded slot (pads trail)
    kv = k(params, X, x[None])[:, 0] * mask  # zero on padded rows
    w = jax.scipy.linalg.solve_triangular(L, kv, lower=True)
    noise = jnp.exp(2.0 * params["log_tau"])
    knn = k(params, x[None], x[None])[0, 0] + noise + _JITTER
    row = w.at[n].set(jnp.sqrt(knn - w @ w))
    return (L.at[n, :].set(row), X.at[n, :].set(x), y.at[n].set(val),
            mask.at[n].set(1.0))


@functools.partial(jax.jit, static_argnames=("kind",))
def _posterior_chol(params, L, X, y, mask, Xs, kind):
    """`_posterior` with the Cholesky factor precomputed (the incremental
    path): identical solves, no per-query refactorization."""
    k = KERNELS[kind]
    c = params["mean_const"]
    r = jnp.where(mask > 0.5, y - c, 0.0)
    alpha = jax.scipy.linalg.cho_solve((L, True), r)
    Ks = k(params, Xs, X) * mask[None, :]
    mu = Ks @ alpha + c
    v = jax.scipy.linalg.solve_triangular(L, Ks.T, lower=True)
    kss = jax.vmap(lambda x: k(params, x[None], x[None])[0, 0])(Xs)
    var = jnp.maximum(kss - jnp.sum(v**2, axis=0), 1e-10)
    return mu, var


@functools.partial(jax.jit, static_argnames=("kind",))
def _posterior(params, X, y, mask, Xs, kind):
    k = KERNELS[kind]
    noise = jnp.exp(2.0 * params["log_tau"])
    diag = jnp.where(mask > 0.5, noise + _JITTER, _PAD_NOISE)
    K = k(params, X, X) * (mask[:, None] * mask[None, :]) + jnp.diag(diag)
    c = params["mean_const"]
    r = jnp.where(mask > 0.5, y - c, 0.0)
    L = jnp.linalg.cholesky(K)
    alpha = jax.scipy.linalg.cho_solve((L, True), r)
    Ks = k(params, Xs, X) * mask[None, :]
    mu = Ks @ alpha + c
    v = jax.scipy.linalg.solve_triangular(L, Ks.T, lower=True)
    kss = jax.vmap(lambda x: k(params, x[None], x[None])[0, 0])(Xs)
    var = jnp.maximum(kss - jnp.sum(v**2, axis=0), 1e-10)
    return mu, var


def apply_prior_mean(mu, ms):
    """Add an externally supplied prior-mean offset `ms` to posterior means
    `mu` (variances are untouched).

    Residual prior-mean contract: the caller fits the GP on residuals
    y - m(x) and adds m back at query time via this helper.  Any
    *ordering-accurate* mean (one that ranks points like the true objective,
    e.g. -log10 of the analytic EDP lower bound, ROADMAP "the bound is
    ordering-accurate") shifts the acquisition landscape toward genuinely
    promising hardware without touching the calibrated posterior variances
    -- the GP only has to learn the (smoother) gap between bound and
    achieved utility."""
    return np.asarray(mu) + np.asarray(ms, dtype=np.float64)


@dataclasses.dataclass
class GP:
    """Exact GP regressor.

    kind:        'se' or 'linear'
    noisy:       if False, the noise is pinned tiny (deterministic evaluator,
                 paper §4.3); if True it is a learned hyperparameter (paper §4.2).
    fit_tol:     gradient-norm early-exit tolerance for the hyperparameter fit
                 (0.0 = off: the fixed-length scan, bit-identical to the
                 historical fit).
    """

    kind: str = "linear"
    noisy: bool = True
    steps: int = 80
    fit_tol: float = 0.0
    _state: tuple | None = None
    # Cached Cholesky factor of the data kernel matrix, maintained by
    # `append_observation` between aligned refits.  None (the default) keeps
    # every posterior on the factor-free `_posterior` path -- the incremental
    # machinery is strictly opt-in, so fitted GPs behave byte-for-byte as
    # before unless the BO loop explicitly appends.
    _fac: jax.Array | None = None

    def fit(self, X: np.ndarray, y: np.ndarray) -> "GP":
        X = np.asarray(X, np.float64)
        y = np.asarray(y, np.float64)
        n, d = X.shape
        b = _bucket(n)
        Xp = np.zeros((b, d))
        yp = np.zeros((b,))
        mask = np.zeros((b,))
        Xp[:n], yp[:n], mask[:n] = X, y, 1.0
        with enable_x64():
            params = _init_params(self.kind, d)
            params["mean_const"] = jnp.asarray(float(y.mean()))
            params["log_tau"] = jnp.asarray(
                np.log(max(y.std(), 1e-3) * 0.1) if self.noisy else -6.0)
            # With noisy=False the pinned log_tau is frozen *during* the fit
            # (zeroed gradient), so the remaining hyperparameters are trained
            # against the true fixed noise level -- no post-fit re-pin needed.
            params = _fit(params, jnp.asarray(Xp), jnp.asarray(yp),
                          jnp.asarray(mask), self.kind, self.steps,
                          train_tau=self.noisy, tol=self.fit_tol)
            self._state = (params, jnp.asarray(Xp), jnp.asarray(yp),
                           jnp.asarray(mask))
        self._fac = None  # a full refit invalidates any incremental factor
        return self

    def posterior(self, Xs: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        mu, var = self.posterior_device(Xs)
        return np.asarray(mu), np.asarray(var)

    def posterior_device(self, Xs) -> tuple[jax.Array, jax.Array]:
        """Posterior as device arrays -- lets the batched-engine acquisition
        scoring stay device-resident (no host round-trip per BO trial).
        With an incremental factor cached (`append_observation`), reuses it
        instead of refactorizing per call."""
        assert self._state is not None, "fit() first"
        params, Xp, yp, mask = self._state
        with enable_x64():
            Xs = jnp.asarray(Xs, jnp.float64)
            if self._fac is not None:
                return _posterior_chol(params, self._fac, Xp, yp, mask, Xs,
                                       self.kind)
            return _posterior(params, Xp, yp, mask, Xs, self.kind)

    def append_observation(self, x: np.ndarray, y: float) -> "GP":
        """Fold one observation into the posterior WITHOUT refitting
        hyperparameters: an O(n^2) rank-1 border update of the cached Cholesky
        factor (built lazily on first append).  Between aligned refits this
        keeps the surrogate's data current at a fraction of a full fit's cost;
        the next `fit()` discards the factor and re-learns hyperparameters as
        usual.  Parity: matches `with_data` (frozen-hyperparameter refit from
        scratch) to <= 1e-8."""
        assert self._state is not None, "fit() first"
        params, Xp, yp, mask = self._state
        n = int(np.asarray(mask).sum())
        b = Xp.shape[0]
        with enable_x64():
            if n >= b:
                # Bucket overflow: repad to the next bucket and refactorize
                # (O(n^3), but only at power-of-two boundaries -- amortized
                # O(n^2) per append).
                b2 = _bucket(n + 1)
                Xp2 = np.zeros((b2, Xp.shape[1]))
                yp2 = np.zeros((b2,))
                mask2 = np.zeros((b2,))
                Xp2[:n] = np.asarray(Xp)[:n]
                yp2[:n] = np.asarray(yp)[:n]
                mask2[:n] = 1.0
                Xp, yp, mask = (jnp.asarray(Xp2), jnp.asarray(yp2),
                                jnp.asarray(mask2))
                self._fac = None
            if self._fac is None:
                self._fac = _chol_factor(params, Xp, mask, self.kind)
            self._fac, Xp, yp, mask = _append_row(
                params, self._fac, Xp, yp, mask,
                jnp.asarray(np.asarray(x, np.float64)), float(y), self.kind)
        self._state = (params, Xp, yp, mask)
        return self

    def with_data(self, X: np.ndarray, y: np.ndarray) -> "GP":
        """A new GP with THIS model's (frozen) hyperparameters and the given
        dataset, state rebuilt from scratch -- the refit-from-scratch parity
        reference for `append_observation`."""
        assert self._state is not None, "fit() first"
        params = self._state[0]
        X = np.asarray(X, np.float64)
        y = np.asarray(y, np.float64)
        n, d = X.shape
        b = _bucket(n)
        Xp = np.zeros((b, d))
        yp = np.zeros((b,))
        mask = np.zeros((b,))
        Xp[:n], yp[:n], mask[:n] = X, y, 1.0
        other = GP(kind=self.kind, noisy=self.noisy, steps=self.steps,
                   fit_tol=self.fit_tol)
        with enable_x64():
            other._state = (params, jnp.asarray(Xp), jnp.asarray(yp),
                            jnp.asarray(mask))
        return other

    @property
    def params(self):
        return self._state[0] if self._state else None


@dataclasses.dataclass
class GPClassifier:
    """GP "classifier" for unknown (output) constraints (paper §3.4): GP
    regression on +/-1 labels with a probit link on the latent posterior --
    the standard cheap approximation used in constrained BO."""

    steps: int = 80
    _gp: GP | None = None

    def fit(self, X: np.ndarray, feasible: np.ndarray) -> "GPClassifier":
        y = np.where(np.asarray(feasible), 1.0, -1.0)
        self._gp = GP(kind="se", noisy=True, steps=self.steps).fit(X, y)
        return self

    def prob_feasible(self, Xs: np.ndarray) -> np.ndarray:
        """Host-side P(feasible): returns a plain NumPy array.  (The erf runs
        on the host -- a JAX array here would silently promote the whole
        acquisition computation in `bo_maximize` to device arrays with a
        blocking transfer per trial.)"""
        if self._gp is None:
            return np.ones(len(Xs))
        mu, var = self._gp.posterior(Xs)
        z = mu / np.sqrt(1.0 + var)
        return 0.5 * (1.0 + _erf(z / np.sqrt(2.0)))

    def prob_feasible_device(self, Xs) -> jax.Array:
        """Device-resident twin of `prob_feasible` for the fused scoring path.
        (The erf must trace under scoped x64, or its internal constants
        canonicalize to f32 and poison the f64 posterior's precision.  Even
        then jax's and scipy's erf differ by ~1e-8 -- implementation, not
        dtype -- so host/device probabilities agree to ~1e-8, far below
        anything the acquisition argmax can resolve.)"""
        if self._gp is None:
            return jnp.ones(len(Xs))
        mu, var = self._gp.posterior_device(Xs)
        with enable_x64():
            z = mu / jnp.sqrt(1.0 + var)
            return 0.5 * (1.0 + jax.scipy.special.erf(z / np.sqrt(2.0)))


# --- stacked (multi-run) GPs ----------------------------------------------------

@functools.partial(jax.jit, static_argnames=("kind", "steps", "train_tau"))
def _fit_stack(params, X, y, mask, kind, steps, train_tau):
    """Batched `_fit` over the leading run axis (params leaves lead with L).

    `lax.map` rather than `vmap`: one compiled program / one dispatch either
    way, but per-slice execution keeps the single-GP linalg kernels, which on
    CPU beat the batched-cholesky lowering badly as the data bucket grows
    (~2.5x at 128 rows) while matching it below.  Per-slice numerics are the
    single-run `_fit`'s exactly.  (On accelerators with real batched linalg
    the vmap form may win again -- revisit with a hardware run.)

    Above `_LOWRANK_MIN_ROWS` data rows the linear kernel (the objective
    surrogate) fits through the Woodbury NLL (`lowrank=True`): the per-trial
    refit is the layer-batched search's dominant cost, and the low-rank form
    cuts it from O(n^3) to O(n d^2) per Adam step.  It computes the same NLL
    to f64 roundoff, but through the ill-conditioned quad-term subtraction its
    gradients drift from the Cholesky path's by ~1e-8 relative, which after 80
    Adam steps perturbs the posterior at the ~1e-7 level -- statistically
    nothing, but not the bit-identical-to-sequential regime the small buckets
    keep (the bucket is a static shape, so the switch is deterministic and
    visible in the jit cache, and searches that never exceed the threshold
    reproduce L sequential `bo_maximize` runs exactly)."""
    lowrank = kind == "linear" and X.shape[1] > _LOWRANK_MIN_ROWS
    return jax.lax.map(
        lambda a: _fit(a[0], a[1], a[2], a[3], kind, steps, 0.05, train_tau,
                       lowrank=lowrank),
        (params, X, y, mask))


@functools.partial(jax.jit, static_argnames=("kind",))
def _posterior_stack(params, X, y, mask, Xs, kind):
    """Batched `_posterior`: (L, P, d) pools -> (L, P) mu/var (lax.map, see
    `_fit_stack`)."""
    return jax.lax.map(
        lambda a: _posterior(a[0], a[1], a[2], a[3], a[4], kind),
        (params, X, y, mask, Xs))


def _bucket_stack(n: int) -> int:
    """Finer-grained buckets for the stacked fit: multiples of 8 up to 64
    rows, multiples of 32 beyond.  The multi-run surrogate refit dominates the
    layer-batched search's per-trial cost, so the padding waste of
    power-of-two buckets (rows up to 2x -> Cholesky flops up to 8x just below
    a boundary) costs more than the extra compile-cache entries.  Padding
    rows are exactly zero-influence (see module docstring), so the bucket
    choice is purely a flops/compile-count tradeoff -- results are
    unchanged."""
    if n <= 8:
        return 8
    step = 8 if n <= 64 else 32
    return -(-n // step) * step


def _pad_runs(Xs, ys) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Stack ragged per-run datasets to (L, b, d)/(L, b) with (L, b) masks,
    b = shared fine-grained bucket over the largest run."""
    L = len(Xs)
    d = Xs[0].shape[1]
    b = _bucket_stack(max(len(y) for y in ys))
    X = np.zeros((L, b, d))
    y = np.zeros((L, b))
    mask = np.zeros((L, b))
    for k, (Xk, yk) in enumerate(zip(Xs, ys)):
        n = len(yk)
        X[k, :n], y[k, :n], mask[k, :n] = Xk, yk, 1.0
    return X, y, mask


@functools.lru_cache(maxsize=None)
def _acq_device_cached(name: str, lam: float):
    """One device-acquisition closure per (name, lam): the SAME function the
    op-by-op scoring paths use, with a stable identity so it can serve as a
    static jit argument of `_score_stack` (a fresh closure per call would
    defeat the jit cache)."""
    from repro.core.acquisition import make_acquisition_device

    return make_acquisition_device(name, lam)


@functools.partial(jax.jit, static_argnames=("kind", "acq_fn"))
def _score_stack(params, X, y, mask, feats, best, kind, acq_fn):
    """Fused multi-run pool scoring: stacked posterior + acquisition + per-run
    argmax + winner-row gather, one compiled program.  The acquisition is the
    `make_acquisition_device` closure itself (traced inline), so the fused
    path computes exactly what the op-by-op paths compute -- no second copy of
    the acquisition math to drift."""
    mu, var = _posterior_stack(params, X, y, mask, feats, kind)
    util = acq_fn(mu, var, best)
    idx = jnp.argmax(util, axis=1)
    rows = jnp.take_along_axis(feats, idx[:, None, None], axis=1)[:, 0, :]
    return idx, rows


@dataclasses.dataclass
class GPStack:
    """L independent exact GP regressors, fit and queried as one batched
    program.  Per-slice numerics match the individual `GP` (same `_fit` /
    `_posterior` bodies per slice of a `lax.map`; padding is exactly
    zero-influence),
    so a stacked multi-run BO engine reproduces L sequential runs.

    kind / noisy / steps: as on `GP`, shared across the stack (the runs are
    peers -- per-layer searches of one hardware probe).
    """

    kind: str = "linear"
    noisy: bool = True
    steps: int = 80
    _state: tuple | None = None

    def fit(self, Xs, ys) -> "GPStack":
        """Fit from per-run datasets: Xs[k] is (n_k, d), ys[k] is (n_k,)."""
        Xs = [np.asarray(Xk, np.float64) for Xk in Xs]
        ys = [np.asarray(yk, np.float64) for yk in ys]
        X, y, mask = _pad_runs(Xs, ys)
        L, _, d = X.shape
        with enable_x64():
            params = jax.tree.map(
                lambda leaf: jnp.broadcast_to(leaf, (L, *leaf.shape)),
                _init_params(self.kind, d))
            params = dict(
                params,
                mean_const=jnp.asarray([float(yk.mean()) for yk in ys]),
                log_tau=jnp.asarray(
                    [np.log(max(yk.std(), 1e-3) * 0.1) for yk in ys]
                    if self.noisy else [-6.0] * L),
            )
            params = _fit_stack(params, jnp.asarray(X), jnp.asarray(y),
                                jnp.asarray(mask), self.kind, self.steps,
                                self.noisy)
            self._state = (params, jnp.asarray(X), jnp.asarray(y),
                           jnp.asarray(mask))
        return self

    def __len__(self) -> int:
        return int(self._state[1].shape[0]) if self._state else 0

    def posterior(self, Xs) -> tuple[np.ndarray, np.ndarray]:
        mu, var = self.posterior_device(Xs)
        return np.asarray(mu), np.asarray(var)

    def posterior_device(self, Xs) -> tuple[jax.Array, jax.Array]:
        """Stacked posterior: Xs is (L, P, d) -- one candidate pool per run --
        returning (L, P) device arrays (the fused multi-run scoring path)."""
        assert self._state is not None, "fit() first"
        params, Xp, yp, mask = self._state
        with enable_x64():
            Xs = jnp.asarray(Xs, jnp.float64)
            return _posterior_stack(params, Xp, yp, mask, Xs, self.kind)

    def score_device(
        self, feats, best, acquisition: str = "lcb", lam: float = 1.0,
    ) -> tuple[np.ndarray, np.ndarray]:
        """One-dispatch pool scoring for the multi-run BO trial: stacked
        posterior, acquisition (vs per-run incumbents `best`, shape (L, 1)),
        per-run argmax, and the winners' feature rows -- only the (L,) indices
        and (L, d) rows return to the host."""
        assert self._state is not None, "fit() first"
        params, Xp, yp, mask = self._state
        acq_fn = _acq_device_cached(acquisition, float(lam))
        with enable_x64():
            idx, rows = _score_stack(
                params, Xp, yp, mask,
                jnp.asarray(feats, jnp.float64), jnp.asarray(best, jnp.float64),
                self.kind, acq_fn)
        return np.asarray(idx), np.asarray(rows, dtype=np.float64)


@dataclasses.dataclass
class GPClassifierStack:
    """Stacked twin of `GPClassifier`: L per-run feasibility classifiers
    (SE-kernel GP regression on +/-1 labels, probit link) fit as one batched
    program for the multi-run BO engine's unknown-constraint weighting."""

    steps: int = 80
    _stack: GPStack | None = None

    def fit(self, Xs, feas) -> "GPClassifierStack":
        ys = [np.where(np.asarray(f), 1.0, -1.0) for f in feas]
        self._stack = GPStack(kind="se", noisy=True, steps=self.steps).fit(Xs, ys)
        return self

    def prob_feasible(self, Xs) -> np.ndarray:
        """Host-side (L, P) P(feasible) -- NumPy + scipy erf, mirroring
        `GPClassifier.prob_feasible` exactly so the multi-run host scoring
        path picks the same candidates as L sequential runs."""
        assert self._stack is not None, "fit() first"
        mu, var = self._stack.posterior(Xs)
        z = mu / np.sqrt(1.0 + var)
        return 0.5 * (1.0 + _erf(z / np.sqrt(2.0)))

    def prob_feasible_device(self, Xs) -> jax.Array:
        """(L, P) P(feasible) as device arrays (see `GPClassifier` notes on
        erf precision under scoped x64)."""
        assert self._stack is not None, "fit() first"
        mu, var = self._stack.posterior_device(Xs)
        with enable_x64():
            z = mu / jnp.sqrt(1.0 + var)
            return 0.5 * (1.0 + jax.scipy.special.erf(z / np.sqrt(2.0)))
