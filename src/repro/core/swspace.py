"""Software-mapping search space for one (hardware, layer) pair (paper §4.3).

All constraints are *known* here (hardware and layer are fixed), so the sampler
enforces them as input constraints; the evaluator is deterministic, so the GP
uses no noise kernel.  Features follow Fig. 13 plus order-sensitive log trip
counts, which give the linear kernel direct visibility into the reuse structure.

The space implements the BO loop's batched evaluation protocol on top of
`repro.timeloop.batch`: whole candidate pools are sampled, featurized, and
scored as packed arrays (set `batched=False` to force the scalar reference
path, e.g. for speedup benchmarking).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.timeloop import batch as tlb
from repro.timeloop.arch import HardwareConfig
from repro.timeloop.mapping import (
    Mapping,
    constrained_random_mapping,
    gb_tiles,
    lb_tiles,
    mapping_is_valid,
)
from repro.timeloop.model import _level_trips, evaluate
from repro.timeloop.workloads import DIMS, RELEVANCE, ConvLayer

FEATURE_NAMES = (
    "input_buffer_usage",
    "weight_buffer_usage",
    "output_buffer_usage",
    "global_buffer_usage",
    "parallelism_ratio_x",
    "parallelism_ratio_y",
    "log_trips_W_gb",
    "log_trips_I_gb",
    "log_trips_O_gb",
    "log_trips_W_dram",
    "log_trips_I_dram",
    "log_trips_O_dram",
    "log_used_pes",
    "log_macs_per_pe",
)


@dataclasses.dataclass
class SoftwareSpace:
    hw: HardwareConfig
    layer: ConvLayer
    name: str = "software"
    batched: bool = True  # expose the batched protocol to the BO loop

    @property
    def feature_dim(self) -> int:
        return len(FEATURE_NAMES)

    @property
    def supports_batch(self) -> bool:
        return self.batched

    def sample(self, rng) -> Mapping:
        return constrained_random_mapping(rng, self.hw, self.layer)

    def is_valid(self, m: Mapping) -> bool:
        return mapping_is_valid(m, self.hw, self.layer)[0]

    def features(self, m: Mapping) -> np.ndarray:
        lb = lb_tiles(m, self.layer)
        gb = gb_tiles(m, self.layer)
        f_gb = {d: m.f("gb", d) for d in DIMS}
        f_dram = {d: m.f("dram", d) for d in DIMS}
        trips = []
        for lvl_factors, order in ((f_gb, m.order_gb), (f_dram, m.order_dram)):
            for t in ("W", "I", "O"):
                trips.append(np.log1p(_level_trips(order, lvl_factors, RELEVANCE[t])))
        used = m.used_pes
        return np.array(
            [
                lb["I"] / self.hw.lb_input,
                lb["W"] / self.hw.lb_weight,
                lb["O"] / self.hw.lb_output,
                (gb["I"] + gb["W"] + gb["O"]) / self.hw.gb_entries,
                m.spatial_x / self.hw.pe_mesh_x,
                m.spatial_y / self.hw.pe_mesh_y,
                *trips[:3],
                *trips[3:],
                np.log1p(used),
                np.log1p(self.layer.macs / used),
            ],
            dtype=np.float64,
        )

    def evaluate(self, m: Mapping) -> tuple[float | None, bool]:
        """Returns (utility, feasible); utility = -log10(EDP), maximized."""
        ev = evaluate(self.hw, m, self.layer)
        if not ev.valid:
            return None, False
        return -float(np.log10(ev.edp)), True

    # --- batched evaluation protocol (repro.timeloop.batch) --------------------

    def sample_pool(self, rng, n: int) -> tlb.MappingBatch | None:
        """n input-valid candidates drawn in vectorized rounds (None if the
        space looks empirically empty)."""
        return tlb.sample_valid_pool(rng, self.hw, self.layer, n)

    def features_batch(self, pool: tlb.MappingBatch) -> np.ndarray:
        return tlb.features_batch(pool, self.hw, self.layer)

    def evaluate_batch(self, pool: tlb.MappingBatch) -> tuple[np.ndarray, np.ndarray]:
        """Returns (utility (B,), feasible (B,)); utility is -log10(EDP) with
        -inf on infeasible rows."""
        ev = tlb.evaluate_batch(self.hw, pool, self.layer)
        feasible = ev["valid"]
        with np.errstate(divide="ignore", invalid="ignore"):
            utility = np.where(feasible, -np.log10(ev["edp"]), -np.inf)
        return utility, feasible
