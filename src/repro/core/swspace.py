"""Software-mapping search space for one (hardware, layer) pair (paper §4.3).

All constraints are *known* here (hardware and layer are fixed), so the sampler
enforces them as input constraints; the evaluator is deterministic, so the GP
uses no noise kernel.  Features follow Fig. 13 plus order-sensitive log trip
counts, which give the linear kernel direct visibility into the reuse structure.

The space implements the BO loop's batched evaluation protocol on top of a
selectable engine:

  backend="numpy"  `repro.timeloop.batch` -- vectorized NumPy (default)
  backend="jax"    `repro.timeloop.batch_jax` -- jitted `jax.vmap` engine with
                   a Pallas inner kernel; additionally exposes
                   `features_batch_device` so the BO loop can keep the GP
                   posterior + acquisition scoring device-resident

`backend=None` reads the `REPRO_BACKEND` environment variable (so CI can run
the whole suite against either engine) and falls back to "numpy".  Candidate
pools are sampled host-side with either backend -- the constrained rejection
sampler is branchy NumPy; only featurization/evaluation/scoring move to JAX.
Set `batched=False` to force the scalar reference path, e.g. for speedup
benchmarking.
"""

from __future__ import annotations

import dataclasses
import os

import numpy as np

from repro.core.cache import SlotCache
from repro.core.config import BACKENDS, PALLAS_MODES, validate_choice
from repro.timeloop import batch as tlb
from repro.timeloop.arch import HardwareConfig
from repro.timeloop.mapping import (
    Mapping,
    constrained_random_mapping,
    gb_tiles,
    lb_tiles,
    mapping_is_valid,
)
from repro.timeloop.model import _level_trips, evaluate
from repro.timeloop.workloads import DIMS, RELEVANCE, ConvLayer

FEATURE_NAMES = (
    "input_buffer_usage",
    "weight_buffer_usage",
    "output_buffer_usage",
    "global_buffer_usage",
    "parallelism_ratio_x",
    "parallelism_ratio_y",
    "log_trips_W_gb",
    "log_trips_I_gb",
    "log_trips_O_gb",
    "log_trips_W_dram",
    "log_trips_I_dram",
    "log_trips_O_dram",
    "log_used_pes",
    "log_macs_per_pe",
)

def default_backend() -> str:
    """Engine selected by $REPRO_BACKEND, falling back to "numpy"."""
    return os.environ.get("REPRO_BACKEND", "numpy")


@dataclasses.dataclass
class SoftwareSpace:
    hw: HardwareConfig
    layer: ConvLayer
    name: str = "software"
    batched: bool = True  # expose the batched protocol to the BO loop
    backend: str | None = None  # "numpy" | "jax" | None -> $REPRO_BACKEND
    pallas_mode: str | None = None  # "jnp"|"pallas"|"interpret"|None -> auto

    def __post_init__(self) -> None:
        if self.backend is None:
            self.backend = default_backend()
        validate_choice("backend", self.backend, BACKENDS)
        validate_choice("pallas_mode", self.pallas_mode, PALLAS_MODES,
                        optional=True)
        # One fused device program computes validity+EDP+features together, so
        # features_batch / evaluate_batch / features_batch_device on the same
        # pool object must share a single dispatch (the BO warmup calls two of
        # them back to back).  One slot: the forward dict holds whole-pool
        # device arrays, so a deeper cache would double peak device memory.
        self._fwd_cache = SlotCache("sw_fwd", capacity=1)
        # NumPy twin of the memo: pool-identity cache for the packed feature
        # matrix, so repeat featurizations of the same pool object (frozen
        # refit windows, outer-loop hooks) are free on either backend.
        self._np_feat_cache = SlotCache("sw_feat", capacity=2)

    def _forward_jax(self, pool) -> dict:
        # Deferred import: the default NumPy backend must not pay for (or
        # depend on) the jax.experimental.pallas import chain.
        from repro.timeloop import batch_jax as jtlb

        out = self._fwd_cache.get(pool)
        if out is None:
            out = jtlb.forward_device(
                self.hw, pool, self.layer, mode=self.pallas_mode)
            self._fwd_cache.put(pool, out)
        return out

    @property
    def feature_dim(self) -> int:
        return len(FEATURE_NAMES)

    @property
    def supports_batch(self) -> bool:
        return self.batched

    @property
    def supports_device(self) -> bool:
        """Whether `features_batch_device` returns device-resident arrays the
        BO loop can score without a host round-trip."""
        return self.batched and self.backend == "jax"

    def sample(self, rng) -> Mapping:
        return constrained_random_mapping(rng, self.hw, self.layer)

    def is_valid(self, m: Mapping) -> bool:
        return mapping_is_valid(m, self.hw, self.layer)[0]

    def features(self, m: Mapping) -> np.ndarray:
        lb = lb_tiles(m, self.layer)
        gb = gb_tiles(m, self.layer)
        f_gb = {d: m.f("gb", d) for d in DIMS}
        f_dram = {d: m.f("dram", d) for d in DIMS}
        trips = []
        for lvl_factors, order in ((f_gb, m.order_gb), (f_dram, m.order_dram)):
            for t in ("W", "I", "O"):
                trips.append(np.log1p(_level_trips(order, lvl_factors, RELEVANCE[t])))
        used = m.used_pes
        return np.array(
            [
                lb["I"] / self.hw.lb_input,
                lb["W"] / self.hw.lb_weight,
                lb["O"] / self.hw.lb_output,
                (gb["I"] + gb["W"] + gb["O"]) / self.hw.gb_entries,
                m.spatial_x / self.hw.pe_mesh_x,
                m.spatial_y / self.hw.pe_mesh_y,
                *trips[:3],
                *trips[3:],
                np.log1p(used),
                np.log1p(self.layer.macs / used),
            ],
            dtype=np.float64,
        )

    def evaluate(self, m: Mapping) -> tuple[float | None, bool]:
        """Returns (utility, feasible); utility = -log10(EDP), maximized."""
        ev = evaluate(self.hw, m, self.layer)
        if not ev.valid:
            return None, False
        return -float(np.log10(ev.edp)), True

    # --- batched evaluation protocol (batch / batch_jax) ------------------------

    def sample_pool(self, rng, n: int) -> tlb.MappingBatch | None:
        """n input-valid candidates drawn in vectorized rounds (None if the
        space looks empirically empty)."""
        return tlb.sample_valid_pool(rng, self.hw, self.layer, n)

    def features_batch(self, pool: tlb.MappingBatch) -> np.ndarray:
        if self.backend == "jax":
            return np.asarray(self._forward_jax(pool)["features"])
        feats = self._np_feat_cache.get(pool)
        if feats is None:
            feats = tlb.features_batch(pool, self.hw, self.layer)
            self._np_feat_cache.put(pool, feats)
        return feats

    def evaluate_batch(self, pool: tlb.MappingBatch) -> tuple[np.ndarray, np.ndarray]:
        """Returns (utility (B,), feasible (B,)); utility is -log10(EDP) with
        -inf on infeasible rows."""
        if self.backend == "jax":
            out = self._forward_jax(pool)
            return np.asarray(out["utility"]), np.asarray(out["valid"])
        ev = tlb.evaluate_batch(self.hw, pool, self.layer)
        feasible = ev["valid"]
        with np.errstate(divide="ignore", invalid="ignore"):
            utility = np.where(feasible, -np.log10(ev["edp"]), -np.inf)
        return utility, feasible

    def features_batch_device(self, pool: tlb.MappingBatch):
        """(B, 14) features as a device-resident jax.Array (JAX backend only)."""
        assert self.backend == "jax", "device features require backend='jax'"
        return self._forward_jax(pool)["features"]


def fanout_spaces(items, *, batched: bool = True, backend: str | None = None,
                  pallas_mode: str | None = None,
                  pad_to: int | None = None) -> list[SoftwareSpace]:
    """Pack (hardware, layer) work items into the `SoftwareSpace` runs of one
    stacked multi-run fan-out (`bo_maximize_many` stacks them through
    `LayerStackSpace`; the hardware vector rides per row).

    `pad_to`: on the JAX backend the fused per-round program is compiled for
    the stack's run count, and the speculative outer loop's count varies per
    trial (already-cached probes drop out) -- padding the stack to a fixed
    width with copies of run 0 keeps ONE compiled program across trials.
    Padded runs are real but redundant searches whose vectorized rows are
    nearly free on-device; callers slice results back to `len(items)`.  On
    NumPy every run costs real host work, so no padding is applied there."""
    spaces = [SoftwareSpace(hw, layer, batched=batched, backend=backend,
                            pallas_mode=pallas_mode)
              for hw, layer in items]
    if (pad_to is not None and spaces and spaces[0].backend == "jax"
            and len(spaces) < pad_to):
        spaces += [dataclasses.replace(spaces[0])
                   for _ in range(pad_to - len(spaces))]
    return spaces


@dataclasses.dataclass
class LayerStackSpace:
    """L `SoftwareSpace` runs advanced as one stacked batch -- the packing
    layer of the layer-batched nested search (all runs share one hardware
    probe) and of the probe-fanout warmup (runs span H hardware probes; the
    hardware vector rides per row exactly like the layer vector).

    The multi-run BO engine (`repro.core.bo.bo_maximize_many`) hands this a
    list of per-run candidate pools (one `MappingBatch` per run) and gets
    back (L, B)-shaped results:

      * `backend="jax"`: all pools are packed into a single (L*B, 5, 6) batch
        and evaluated by ONE fused jitted device program per BO round
        (`batch_jax.forward_device_stacked`, hardware + layer vectors per
        row), with `features_stacked_device` keeping the feature matrix
        device-resident for the fused GP-acquisition scoring chain;
      * `backend="numpy"`: per-space vectorized NumPy calls, stacked host-side
        (no fused program, but the stacked-GP surrogate path still applies).

    Per-row numerics are identical to the per-run `SoftwareSpace` calls, so
    a multi-run search reproduces L sequential `bo_maximize` runs.
    """

    spaces: tuple

    def __post_init__(self) -> None:
        assert self.spaces, "empty stack"
        s0 = self.spaces[0]
        assert all(s.backend == s0.backend and s.pallas_mode == s0.pallas_mode
                   for s in self.spaces)

    @classmethod
    def maybe(cls, spaces) -> "LayerStackSpace | None":
        """Build a stack when the runs are stackable: all `SoftwareSpace`s with
        the batched protocol, one backend, one Pallas mode (hardware configs
        may differ per run -- the probe-fanout case).  Returns None otherwise
        (the BO engine then falls back to lockstep per-space calls)."""
        spaces = tuple(spaces)
        if not spaces or not all(isinstance(s, SoftwareSpace) for s in spaces):
            return None
        if not all(s.supports_batch for s in spaces):
            return None
        if not all(s.backend == spaces[0].backend
                   and s.pallas_mode == spaces[0].pallas_mode
                   for s in spaces):
            return None
        return cls(spaces)

    @property
    def hws(self) -> list[HardwareConfig]:
        return [s.hw for s in self.spaces]

    @property
    def backend(self) -> str:
        return self.spaces[0].backend

    @property
    def supports_device(self) -> bool:
        return self.backend == "jax"

    @property
    def n_runs(self) -> int:
        return len(self.spaces)

    def placeholder_pool(self, n: int) -> tlb.MappingBatch:
        """All-ones pool of length n: benign rows (finite arithmetic, invalid
        under the factorization check) used to keep the stacked program's
        (L, B) shape fixed when some runs sit a round out (no surrogate yet,
        or stopped early) -- a varying run count would recompile the fused
        program."""
        return tlb.MappingBatch(
            factors=np.ones((n, 5, 6), np.int64),
            order_lb=np.tile(np.arange(6, dtype=np.int64), (n, 1)),
            order_gb=np.tile(np.arange(6, dtype=np.int64), (n, 1)),
            order_dram=np.tile(np.arange(6, dtype=np.int64), (n, 1)),
        )

    def _forward_stacked_jax(self, pools) -> dict:
        from repro.timeloop import batch_jax as jtlb

        return jtlb.forward_device_stacked(
            self.hws, pools, [s.layer for s in self.spaces],
            mode=self.spaces[0].pallas_mode)

    def forward_stacked(self, pools, runs=None) -> dict[str, np.ndarray]:
        """Host-side stacked forward over per-run pools (all of equal length):
        dict of `features` (L, B, 14), `utility` (L, B), `valid` (L, B).

        `runs` restricts the NumPy path to the listed run indices (other rows
        stay zero) -- rounds where only a subset of runs participates; the JAX
        path always evaluates the full fixed-(L, B) fused program instead,
        because a shape that tracked the subset would recompile it."""
        B = len(pools[0])
        assert all(len(p) == B for p in pools)
        if self.backend == "jax":
            out = self._forward_stacked_jax(pools)
            return {k: np.asarray(out[k])
                    for k in ("features", "utility", "valid")}
        L = self.n_runs
        feats = np.zeros((L, B, self.spaces[0].feature_dim))
        utility = np.full((L, B), -np.inf)
        valid = np.zeros((L, B), dtype=bool)
        for k in range(L) if runs is None else runs:
            feats[k] = self.spaces[k].features_batch(pools[k])
            utility[k], valid[k] = self.spaces[k].evaluate_batch(pools[k])
        return {"features": feats, "utility": utility, "valid": valid}

    def features_stacked(self, pools, runs=None) -> np.ndarray:
        """(L, B, 14) host feature tensor only -- the per-trial scoring input.
        On NumPy this skips the EDP evaluation entirely (the sequential BO
        trial only featurizes its pool; the winner is evaluated scalar)."""
        B = len(pools[0])
        assert all(len(p) == B for p in pools)
        if self.backend == "jax":
            return np.asarray(self._forward_stacked_jax(pools)["features"])
        feats = np.zeros((self.n_runs, B, self.spaces[0].feature_dim))
        for k in range(self.n_runs) if runs is None else runs:
            feats[k] = self.spaces[k].features_batch(pools[k])
        return feats

    def features_stacked_device(self, pools):
        """(L, B, 14) device-resident features for the fused multi-run GP
        scoring chain (JAX backend only)."""
        assert self.supports_device
        return self._forward_stacked_jax(pools)["features"]
