"""Jit'd public wrappers for the Pallas kernels.

On TPU these dispatch to the compiled kernels (interpret=False); everywhere
else (this CPU container, unit tests) they run the kernel body in interpret
mode, which executes the same code path block-by-block in Python.
"""

from __future__ import annotations

import jax

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.tiled_matmul import tiled_matmul as _matmul


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def matmul(x, w, bm: int = 256, bk: int = 512, bn: int = 256):
    return _matmul(x, w, bm=bm, bk=bk, bn=bn, interpret=not _on_tpu())


def attention(q, k, v, bq: int = 512, bk: int = 512):
    return _flash(q, k, v, bq=bq, bk=bk, interpret=not _on_tpu())
