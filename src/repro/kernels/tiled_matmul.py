"""Block-tiled matmul Pallas kernel with BO-tunable BlockSpecs.

This is the TPU-native analogue of the paper's *software mapping*: the block
shapes (bm, bk, bn) are the loop-blocking factors (S1-S6), the grid order is
the loop order (S7-S9), and the VMEM capacity bound is the buffer-capacity
constraint.  `repro.core.autotune` searches this space with the same
constrained-BO machinery used for the accelerator co-design.

Layout: grid (M/bm, N/bn, K/bk) with K innermost; the fp32 accumulator lives in
a VMEM scratch buffer across the K steps, flushed to the output tile on the
last K step -- the standard MXU-friendly schedule.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def vmem_bytes(bm: int, bk: int, bn: int, in_dtype=jnp.bfloat16) -> int:
    """VMEM working set claimed by the BlockSpecs (input, weight, out, acc)."""
    ib = jnp.dtype(in_dtype).itemsize
    return bm * bk * ib + bk * bn * ib + bm * bn * ib + bm * bn * 4


def block_is_valid(m: int, k: int, n: int, bm: int, bk: int, bn: int,
                   vmem_limit: int = 96 * 2 ** 20) -> tuple[bool, str]:
    """Input constraints for the block-shape search space (paper-style)."""
    if m % bm or k % bk or n % bn:
        return False, "divisibility"
    if bm % 8 or bk % 128 or bn % 128:
        return False, "mxu_alignment"  # (8,128) VREG tiling / 128-lane MXU
    if vmem_bytes(bm, bk, bn) > vmem_limit:
        return False, "vmem_capacity"
    return True, "ok"


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def tiled_matmul(x, w, bm: int = 256, bk: int = 512, bn: int = 256,
                 interpret: bool = False):
    """x: (M, K) @ w: (K, N) -> (M, N) via an explicitly tiled Pallas kernel."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, "divisibility"
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)
