"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import jax.numpy as jnp
import jax


def matmul_ref(x, w):
    return jnp.asarray(x) @ jnp.asarray(w)


def flash_attention_ref(q, k, v):
    """Causal GQA attention, materialized-scores reference.
    q: (B,Sq,H,hd); k,v: (B,Sk,KV,hd) -> (B,Sq,H,hd)."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    g = H // KV
    qq = q.reshape(B, Sq, KV, g, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qq, k.astype(jnp.float32)) * hd ** -0.5
    mask = jnp.arange(Sk)[None, :] <= jnp.arange(Sq)[:, None]
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)
