"""Flash-attention forward Pallas TPU kernel (causal, tunable blocks).

Grid: (batch*kv_heads, Sq/bq, Sk/bk) with the K axis innermost; online-softmax
running state (m, l) and the output accumulator live in VMEM scratch across the
K steps.  BlockSpecs stage (bq x hd) query tiles and (bk x hd) key/value tiles
HBM->VMEM; block sizes are BO-tunable with the same VMEM-capacity input
constraints as the tiled matmul (see repro.core.autotune).

The kernel handles one (batch, kv-head) pair per grid row with the GQA group
folded into the query tile: q rows are (g * bq, hd).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, bq: int, bk: int, n_k: int, g: int, scale: float):
    kk = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(kk == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                       # (g*bq, hd)
    k = k_ref[0]                       # (bk, hd)
    v = v_ref[0]                       # (bk, hd)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale      # (g*bq, bk)

    # q rows are position-major: row r covers position qi*bq + r // g.
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (g * bq, bk), 0) // g
    kpos = kk * bk + jax.lax.broadcasted_iota(jnp.int32, (g * bq, bk), 1)
    s = jnp.where(kpos <= qpos, s, _NEG)

    m_prev = m_ref[...]                # (g*bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(kk == n_k - 1)
    def _():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bk", "interpret"))
def flash_attention(q, k, v, bq: int = 512, bk: int = 512,
                    interpret: bool = False):
    """Causal GQA flash attention.

    q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd); H = g * KV.  Returns (B, Sq, H, hd).
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    g = H // KV
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, "divisibility"
    scale = hd ** -0.5

    # Layout: fold (B, KV) into the grid's leading axis; q rows position-major
    # within a tile so tiles are contiguous position ranges.
    qg = q.reshape(B, Sq, KV, g, hd).transpose(0, 2, 1, 3, 4).reshape(B * KV, Sq * g, hd)
    kg = k.transpose(0, 2, 1, 3).reshape(B * KV, Sk, hd)
    vg = v.transpose(0, 2, 1, 3).reshape(B * KV, Sk, hd)

    grid = (B * KV, Sq // bq, Sk // bk)
    n_k = Sk // bk

    def q_index(b, i, kk):
        return (b, i, 0)

    def kv_index(b, i, kk):
        return (b, kk, 0)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bk=bk, n_k=n_k, g=g, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, g * bq, hd), q_index),
            pl.BlockSpec((1, bk, hd), kv_index),
            pl.BlockSpec((1, bk, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, g * bq, hd), q_index),
        out_shape=jax.ShapeDtypeStruct((B * KV, g * Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g * bq, 1), jnp.float32),
            pltpu.VMEM((g * bq, 1), jnp.float32),
            pltpu.VMEM((g * bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kg, vg)

    out = out.reshape(B, KV, Sq, g, hd).transpose(0, 2, 1, 3, 4)
    return out.reshape(B, Sq, H, hd)
