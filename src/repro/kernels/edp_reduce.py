"""Pallas kernel for the per-mapping trip-count / energy reduction.

This is the innermost arithmetic of the analytical cost model (`model.evaluate`
-> `batch.evaluate_batch`): for each candidate mapping, reduce the per-level
loop factors into refetch trip counts (the Timeloop temporal-reuse rule),
read-modify-write passes, and finally the energy / delay / EDP scalars.

The numerics live in `reduce_edp_terms`, a batched pure-`jnp` function used two
ways:

  * called directly on full `(B, ...)` arrays -- the `jnp` fallback path that
    CPU CI runs (and the reference the kernel is parity-tested against);
  * called blockwise inside `_edp_kernel`, the Pallas kernel body, via
    `edp_reduce(..., interpret=...)` -- compiled on TPU, interpreter-mode
    elsewhere.

Both paths are driven by `repro.timeloop.batch_jax`; see that module for the
packed operand layout.

Operand layout (all leading dim B):

  fo     (B, 2, 6)     loop factors *in loop order* at [gb, dram] level
  relo   (B, 2, 3, 6)  0/1 relevance per [level, tensor(W,I,O), loop position]
  tiles  (B, 2, 3)     [lb, gb] x [W, I, O] tile sizes
  sp     (B, 6)        [sp_rel_W, sp_rel_I, sp_rel_O, sp_all, used_pes, macs]
  consts (B, 7)        [e_mac, e_lb, e_noc, e_gb, e_dram, gb_bw, dram_bw]

`macs` rides with the per-row operands (not a shared constant) because rows of
one batch may belong to *different layers*: the layer-stacked nested search
packs all layers' candidate pools into a single (L*B,)-row program per
hardware probe, so every layer-dependent quantity must be per-row.  The
energy/bandwidth constants are per-row for the same reason one level up: the
probe-fanout nested search stacks the pools of H different *hardware* probes
into one (H*L*B,)-row program, so the hardware-dependent quantities ride per
row too (single-probe callers just broadcast one row).

Outputs:

  ev     (B, 3)        [energy_pj, delay_cycles, edp]
  trips  (B, 6)        refetch trips [W, I, O]@gb then [W, I, O]@dram
                       (feature inputs: `features_batch` takes log1p of these)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

N_DIMS = 6
N_TENSORS = 3


def reduce_edp_terms(fo, relo, tiles, sp, consts):
    """Batched trip-count + energy reduction (see module docstring for shapes).

    Mirrors `repro.timeloop.model.evaluate` / `batch.evaluate_batch` exactly;
    pure `jnp`, so it runs unchanged as the fallback path and as the Pallas
    kernel body (where the leading dim is the block size).
    """
    n = fo.shape[0]
    dtype = fo.dtype
    one = jnp.ones((), dtype)
    pos = jax.lax.broadcasted_iota(jnp.int32, (n, N_DIMS), 1)

    def level_trips(f, r):
        # f: (n, 6) factors in loop order; r: (n, 6) 0/1 relevance mask.
        rel = r > 0.5
        active = rel & (f > 1.0)
        innermost = jnp.max(jnp.where(active, pos, -1), axis=1)
        include = rel | (pos < innermost[:, None])
        t = jnp.prod(jnp.where(include, f, one), axis=1)
        return jnp.where(jnp.any(active, axis=1), t, one)

    def passes(f, r):
        # Reduction passes for outputs: irrelevant loops outside all relevant.
        rel = r > 0.5
        active = rel & (f > 1.0)
        anchor = jnp.min(jnp.where(active, pos, N_DIMS), axis=1)
        include = (~rel) & (pos < anchor[:, None])
        return jnp.prod(jnp.where(include, f, one), axis=1)

    e_mac, e_lb, e_noc, e_gb, e_dram, gb_bw, dram_bw = (
        consts[:, i] for i in range(7)
    )
    macs = sp[:, 5]

    trips = [
        level_trips(fo[:, li, :], relo[:, li, ti, :])
        for li in range(2)
        for ti in range(N_TENSORS)
    ]
    rw_gb = 2.0 * passes(fo[:, 0, :], relo[:, 0, 2, :]) - 1.0
    rw_dram = 2.0 * passes(fo[:, 1, :], relo[:, 1, 2, :]) - 1.0

    sp_all = sp[:, 3]
    used = sp[:, 4]
    lb_acc = jnp.zeros((n,), dtype)
    noc_acc = jnp.zeros((n,), dtype)
    gb_acc = jnp.zeros((n,), dtype)
    dram_acc = jnp.zeros((n,), dtype)
    for ti in range(N_TENSORS):
        gb_trips = trips[ti]
        dram_trips = trips[N_TENSORS + ti]
        rw = rw_gb if ti == 2 else one
        rw_d = rw_dram if ti == 2 else one
        fills_lb = tiles[:, 0, ti] * gb_trips * dram_trips
        gb_acc += fills_lb * sp[:, ti] * rw
        noc_acc += fills_lb * sp_all * rw
        lb_acc += fills_lb * sp_all * rw
        dram_acc += tiles[:, 1, ti] * dram_trips * rw_d
    lb_acc += 4.0 * macs

    energy = (
        macs * e_mac
        + lb_acc * e_lb
        + noc_acc * e_noc
        + gb_acc * e_gb
        + dram_acc * e_dram
    )
    delay = jnp.maximum(
        macs / used, jnp.maximum(gb_acc / gb_bw, dram_acc / dram_bw)
    )
    ev = jnp.stack([energy, delay, energy * delay], axis=1)
    return ev, jnp.stack(trips, axis=1)


def _edp_kernel(fo_ref, relo_ref, tiles_ref, sp_ref, consts_ref, ev_ref, trips_ref):
    ev, trips = reduce_edp_terms(
        fo_ref[...], relo_ref[...], tiles_ref[...], sp_ref[...], consts_ref[...]
    )
    ev_ref[...] = ev
    trips_ref[...] = trips


def edp_reduce(fo, relo, tiles, sp, consts, *, block: int = 128,
               interpret: bool = True):
    """Pallas dispatch of `reduce_edp_terms`, blocked over the pool dim.

    The block size is shrunk (by halving) to the largest power of two that
    divides the pool dim: single-layer callers pad pools to power-of-two
    buckets (any `min(block, B)` divides), while the layer-stacked program
    flattens L such buckets into an L*bucket-row batch, which is divisible by
    the bucket but not necessarily by 128.  `interpret=True` runs the kernel
    body block-by-block in Python -- the CPU CI path; `interpret=False`
    compiles for the accelerator.
    """
    n = fo.shape[0]
    blk = min(block, n)
    while n % blk:
        blk //= 2
    grid = (n // blk,)
    return pl.pallas_call(
        _edp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, 2, N_DIMS), lambda i: (i, 0, 0)),
            pl.BlockSpec((blk, 2, N_TENSORS, N_DIMS), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((blk, 2, N_TENSORS), lambda i: (i, 0, 0)),
            pl.BlockSpec((blk, 6), lambda i: (i, 0)),
            pl.BlockSpec((blk, 7), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((blk, 3), lambda i: (i, 0)),
            pl.BlockSpec((blk, N_DIMS), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 3), fo.dtype),
            jax.ShapeDtypeStruct((n, N_DIMS), fo.dtype),
        ],
        interpret=interpret,
    )(fo, relo, tiles, sp, consts)
