"""Zoo workload generation: `ModelConfig` -> named `ConvLayer` sets.

Every matmul-shaped term in `repro.models.flops` becomes a `ConvLayer` in the
standard GEMM-as-1x1-conv encoding (d_in -> C, d_out -> K, token tile -> P);
the one genuinely convolutional term (the rglru temporal conv) becomes a real
conv layer.  A per-block-kind extractor registry (`BLOCK_EXTRACTORS`) emits
`(role, layer, count)` items per block instance; assembly dedups identical
shapes (e.g. a Q and O projection when `num_heads * head_dim == d_model`, or
a dense FFN and a same-shaped MoE expert) by summing their counts, so the
searched set stays small (4-10 unique layers per model) while the counts keep
the full-model MACs bookkeeping exact.

The contract that keeps generated shapes provably consistent with the repo's
own cost math: `2 * sum(count * layer.macs)` must equal
`forward_flops(cfg, ZOO_SHAPE)` up to the *documented* non-matmul remainder
-- attention scores+PV at the 64-token tile (ctx averages 32), and a handful
of elementwise gate/normalizer terms.  Generation raises if coverage falls
outside `[1 - MACS_RTOL, 1]`; the measured per-model coverage ships in
`ZooWorkload.coverage` and is pinned by tests.

Extractor registry contract (for adding a block kind): an extractor takes the
`ModelConfig` and returns `[(role, ConvLayer, count_per_block), ...]` covering
every matmul term of the matching `_<kind>_flops_per_token` formula in
`repro/models/flops.py` exactly, skipping only sub-quadratic terms -- then
the cross-check holds automatically for every model using that kind.
"""

from __future__ import annotations

import dataclasses
import functools

from repro.configs.base import ARCH_IDS, ModelConfig, ShapeConfig, get_config
from repro.models.flops import forward_flops
from repro.timeloop.workloads import _TOKENS, MODEL_LAYERS, ConvLayer, fc

# The shape cell every zoo set is generated (and cross-checked) at: one
# 64-token training tile, matching the paper workloads' `_TOKENS` GEMM
# encoding. `forward_flops` at this shape uses tokens = 64 and causal average
# context 32.
ZOO_SHAPE = ShapeConfig(name="zoo_tile", seq_len=_TOKENS, global_batch=1,
                        kind="train")

# Measured non-matmul remainder across the 10-model zoo: 0.03%-0.54%, worst
# on smollm-360m (smallest d_model, so the skipped scores+PV and elementwise
# terms weigh the most); generation fails loudly outside [1 - MACS_RTOL, 1].
MACS_RTOL = 0.01

_Item = tuple[str, ConvLayer, int]


def _attn_items(cfg: ModelConfig, tokens: int = _TOKENS) -> list[_Item]:
    # proj = 2*D*(H + 2*KV)*hd + 2*H*hd*D; scores+pv (2*2*ctx*H*hd) skipped.
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return [
        ("attn_q", fc("attn_q", D, H * hd, tokens), 1),
        ("attn_kv", fc("attn_kv", D, KV * hd, tokens), 2),
        ("attn_o", fc("attn_o", H * hd, D, tokens), 1),
    ]


def _mlp_items(cfg: ModelConfig, tokens: int = _TOKENS) -> list[_Item]:
    # 6*D*d_ff = gated up + gate (2x) + down (1x).
    if not cfg.d_ff:
        return []
    D, F = cfg.d_model, cfg.d_ff
    return [
        ("mlp_up", fc("mlp_up", D, F, tokens), 2),
        ("mlp_down", fc("mlp_down", F, D, tokens), 1),
    ]


def _moe_items(cfg: ModelConfig) -> list[_Item]:
    # router = 2*D*E, experts = top_k * 6*D*d_ff (active experts only).
    D, E, k, F = cfg.d_model, cfg.num_experts, cfg.top_k, cfg.d_ff
    return [
        ("moe_router", fc("moe_router", D, E, _TOKENS), 1),
        ("moe_up", fc("moe_up", D, F, _TOKENS), 2 * k),
        ("moe_down", fc("moe_down", F, D, _TOKENS), k),
    ]


def _mlstm_items(cfg: ModelConfig) -> list[_Item]:
    # proj = 2*D*Din*2 + 2*Din*D + 3*2*Din*dh (+ 2*4*Din elementwise, skipped);
    # cell = 4*Lc*Din (intra-chunk, Lc = mlstm_chunk in train) + 6*dh*Din.
    D = cfg.d_model
    Din = 2 * D
    dh = Din // cfg.num_heads
    Lc = cfg.mlstm_chunk
    return [
        ("mlstm_in", fc("mlstm_in", D, Din, _TOKENS), 2),
        ("mlstm_out", fc("mlstm_out", Din, D, _TOKENS), 1),
        ("mlstm_qkv", fc("mlstm_qkv", Din, dh, _TOKENS), 3),
        ("mlstm_intra", fc("mlstm_intra", Lc, Din, _TOKENS), 2),
        ("mlstm_cell", fc("mlstm_cell", dh, Din, _TOKENS), 3),
    ]


def _slstm_items(cfg: ModelConfig) -> list[_Item]:
    # 4*2*D*D (gates) + 4*2*D*dh (recurrent) + 2*D*D (out) + 6*D*F (FFN);
    # fully matmul -- this extractor is exact.
    D = cfg.d_model
    dh = D // cfg.num_heads
    F = ((4 * D // 3 + 63) // 64) * 64
    return [
        ("slstm_gates", fc("slstm_gates", D, D, _TOKENS), 4),
        ("slstm_rec", fc("slstm_rec", D, dh, _TOKENS), 4),
        ("slstm_out", fc("slstm_out", D, D, _TOKENS), 1),
        ("slstm_ffn_up", fc("slstm_ffn_up", D, F, _TOKENS), 2),
        ("slstm_ffn_down", fc("slstm_ffn_down", F, D, _TOKENS), 1),
    ]


def _rglru_items(cfg: ModelConfig) -> list[_Item]:
    # 5*2*D*D (gate/proj matmuls) + 2*W*D temporal conv (+ 12*D elementwise,
    # skipped).  The conv is a real depthwise temporal conv over the token
    # axis: R = conv_width taps, K = d_model channels.
    D, W = cfg.d_model, cfg.rglru_conv_width
    conv = ConvLayer(name="rglru_conv", R=W, S=1, P=_TOKENS, Q=1, C=1, K=D)
    return [
        ("rglru_proj", fc("rglru_proj", D, D, _TOKENS), 5),
        ("rglru_conv", conv, 1),
    ]


BLOCK_EXTRACTORS = {
    "attn": lambda cfg: _attn_items(cfg) + _mlp_items(cfg),
    # local attention narrows the (skipped) scores context only; the
    # projections and FFN are identical to global attention.
    "local_attn": lambda cfg: _attn_items(cfg) + _mlp_items(cfg),
    "moe": lambda cfg: _attn_items(cfg) + _moe_items(cfg),
    "mlstm": _mlstm_items,
    "slstm": _slstm_items,
    "rglru": lambda cfg: _rglru_items(cfg) + _mlp_items(cfg),
}


@dataclasses.dataclass(frozen=True)
class ZooWorkload:
    """A generated workload set plus its MACs-vs-flops audit trail."""

    arch: str                       # dashed config id ("qwen3-14b")
    name: str                       # registry name ("qwen3_14b")
    layers: tuple[ConvLayer, ...]   # unique shapes, first-occurrence order
    counts: tuple[int, ...]         # full-model replication per layer
    total_macs: int                 # sum(count * layer.macs)
    model_flops: float              # forward_flops(cfg, ZOO_SHAPE)
    coverage: float                 # 2 * total_macs / model_flops


def _norm(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


ZOO_NAMES: tuple[str, ...] = tuple(_norm(a) for a in ARCH_IDS)
_ARCH_BY_NAME: dict[str, str] = {_norm(a): a for a in ARCH_IDS}


def generate_workload(arch: str, cfg: ModelConfig | None = None,
                      tolerance: float = MACS_RTOL) -> ZooWorkload:
    """Build (and MACs-cross-check) the workload set for one model config."""
    cfg = cfg if cfg is not None else get_config(arch)
    pattern = cfg.block_pattern
    if cfg.num_layers % len(pattern) != 0:
        raise ValueError(
            f"{arch}: num_layers={cfg.num_layers} not divisible by the "
            f"{len(pattern)}-entry block_pattern; counts would be fractional")
    per_entry = cfg.num_layers // len(pattern)

    name = _norm(arch)
    order: dict[tuple, list] = {}  # shape key -> [ConvLayer, count]

    def add(role: str, layer: ConvLayer, count: int) -> None:
        key = (layer.R, layer.S, layer.P, layer.Q, layer.C, layer.K,
               layer.stride)
        if key in order:
            order[key][1] += count
        else:
            order[key] = [
                dataclasses.replace(layer, name=f"{name}-{role}"), count]

    for kind in pattern:
        if kind not in BLOCK_EXTRACTORS:
            raise ValueError(
                f"{arch}: no extractor for block kind {kind!r}; known: "
                f"{sorted(BLOCK_EXTRACTORS)}")
        for role, layer, count in BLOCK_EXTRACTORS[kind](cfg):
            add(role, layer, count * per_entry)

    # Tied unembed: tokens * 2 * D * padded_vocab in the train shape.
    add("unembed", fc("unembed", cfg.d_model, cfg.padded_vocab(), _TOKENS), 1)

    if cfg.family == "encdec" and cfg.encoder_layers:
        # Encoder blocks run at the source tile S_src = max(S // 8, 16): a
        # genuinely smaller-token GEMM, kept as distinct `enc_*` shapes.
        s_src = max(ZOO_SHAPE.seq_len // 8, 16)
        for role, layer, count in (_attn_items(cfg, tokens=s_src)
                                   + _mlp_items(cfg, tokens=s_src)):
            add(f"enc_{role}", layer, count * cfg.encoder_layers)
        # Decoder cross-attention: flops.py counts Q/K/V projections but no
        # output projection (`cross` has no `2*H*hd*D` term) -- mirror that.
        for role, layer, count in _attn_items(cfg):
            if role != "attn_o":
                add(role, layer, count * cfg.num_layers)

    layers = tuple(v[0] for v in order.values())
    counts = tuple(int(v[1]) for v in order.values())
    total_macs = sum(c * l.macs for c, l in zip(counts, layers))
    flops = forward_flops(cfg, ZOO_SHAPE)
    coverage = 2.0 * total_macs / flops
    if not (1.0 - tolerance <= coverage <= 1.0 + 1e-9):
        raise ValueError(
            f"zoo workload {name}: extracted MACs cover {coverage:.4f} of "
            f"forward_flops (2*{total_macs} vs {flops:.6g}); expected within "
            f"[{1.0 - tolerance:.3f}, 1.0] -- extractor and "
            "repro/models/flops.py disagree")
    return ZooWorkload(arch=arch, name=name, layers=layers, counts=counts,
                       total_macs=total_macs, model_flops=flops,
                       coverage=coverage)


@functools.lru_cache(maxsize=None)
def _cached_workload(arch: str) -> ZooWorkload:
    return generate_workload(arch)


def zoo_workload(name: str) -> ZooWorkload:
    """Generated (and cross-checked) workload for a zoo model name (dashed
    arch ids and underscored registry names both accepted)."""
    key = _norm(name)
    if key not in _ARCH_BY_NAME:
        raise ValueError(
            f"unknown zoo model {name!r}; known: {sorted(ZOO_NAMES)}")
    return _cached_workload(_ARCH_BY_NAME[key])


def workload_set(name: str) -> list[ConvLayer]:
    """`MODEL_LAYERS`-compatible layer list for a zoo model name."""
    return list(zoo_workload(name).layers)


def known_workloads() -> tuple[str, ...]:
    """Every addressable workload name: the paper's four + the zoo."""
    return tuple(sorted(MODEL_LAYERS)) + tuple(sorted(ZOO_NAMES))


def resolve_workload(name: str) -> list[ConvLayer]:
    """Resolve any workload name -- paper set ("resnet") or zoo model
    ("llama4_maverick_400b_a17b", dashed aliases accepted) -- to layers."""
    if name in MODEL_LAYERS:
        return list(MODEL_LAYERS[name])
    if _norm(name) in _ARCH_BY_NAME:
        return workload_set(name)
    raise ValueError(
        f"unknown workload {name!r}; known: {list(known_workloads())}")
