"""Portfolio co-design: one hardware config for a weighted mix of workloads.

A `PortfolioConfig` names member workload sets (paper or zoo) and their
traffic weights; a `PortfolioSession` scores each outer hardware trial
against ALL members at once -- the union of every member's layers rides the
existing stacked inner-search machinery (`SearchSession.pending()` emits the
whole union, so fused service dispatch and the process executor come along
for free) -- with the trial utility

    u(hw) = -sum_m  w_m * log10(EDP_m(hw))        (w normalized to sum 1)

i.e. the weighted-sum log-EDP = -log10 of the weighted *geometric mean* of
member EDPs, which is what `best_model_edp` reports.  A hardware point with
no feasible mapping for any layer of a positive-weight member is an unknown-
constraint violation (exactly the single-workload rule); zero-weight members
are still searched (they are part of the union stack -- useful for "measure
but don't optimize" traffic) but cannot veto feasibility.  Every feasible
trial's per-member EDP vector is kept, and the non-dominated (Pareto) subset
ships in `CoDesignResult.stats["portfolio_pareto"]`.

Parity contract: with one-hot weights the utility stream collapses to the
single-workload `-log10(total_edp)` bit-for-bit (content-derived probe seeds
make the extra zero-weight members' inner searches trajectory-neutral), so a
one-hot portfolio finds the standalone search's `best_hw` exactly -- pinned
in tests/test_portfolio.py.

Two engine-config restrictions, enforced loudly: `hw.prune` must be "off"
(the EDP lower-bound gate is keyed on a summed-EDP incumbent, which has no
meaning under the weighted objective), and the "sequential" probe strategy is
upgraded to the bit-identical "layer_batched" (`make_portfolio_engine`) --
sequential stops a probe at its first infeasible layer, which would leave
later members' cache entries unevaluated and mis-attribute feasibility.
"""

from __future__ import annotations

import dataclasses
import json
import math

import numpy as np

from repro.core.config import CodesignConfig
from repro.core.nested import (CodesignEngine, CoDesignResult, SearchSession)
from repro.workloads.zoo import resolve_workload


@dataclasses.dataclass(frozen=True)
class PortfolioConfig:
    """Named workload sets + traffic weights (JSON round-trip like the other
    frozen configs).  Empty `weights` means uniform."""

    workloads: tuple[str, ...]
    weights: tuple[float, ...] = ()

    def __post_init__(self) -> None:
        object.__setattr__(self, "workloads", tuple(self.workloads))
        object.__setattr__(
            self, "weights", tuple(float(w) for w in self.weights))
        if not self.workloads:
            raise ValueError("portfolio needs at least one workload")
        if len(set(self.workloads)) != len(self.workloads):
            raise ValueError(
                f"duplicate portfolio workloads: {list(self.workloads)}")
        for name in self.workloads:
            resolve_workload(name)  # raises ValueError listing known names
        if self.weights:
            if len(self.weights) != len(self.workloads):
                raise ValueError(
                    f"{len(self.weights)} weights for "
                    f"{len(self.workloads)} workloads")
            if any(w < 0 or not math.isfinite(w) for w in self.weights):
                raise ValueError(
                    f"weights must be finite and >= 0: {list(self.weights)}")
            if not any(w > 0 for w in self.weights):
                raise ValueError("at least one weight must be positive")

    def normalized_weights(self) -> tuple[float, ...]:
        ws = self.weights or tuple(1.0 for _ in self.workloads)
        total = sum(ws)
        return tuple(w / total for w in ws)

    def to_dict(self) -> dict:
        return {"workloads": list(self.workloads),
                "weights": list(self.weights)}

    @classmethod
    def from_dict(cls, d: dict) -> "PortfolioConfig":
        d = dict(d)
        workloads = d.pop("workloads")
        weights = d.pop("weights", ()) or ()
        if d:
            raise ValueError(f"unknown portfolio keys: {sorted(d)}")
        return cls(workloads=tuple(workloads), weights=tuple(weights))

    def to_json(self) -> str:
        return json.dumps(self.to_dict())

    @classmethod
    def from_json(cls, s: str) -> "PortfolioConfig":
        return cls.from_dict(json.loads(s))


class PortfolioSession(SearchSession):
    """A `SearchSession` over the union of all members' layers whose outer
    objective is the weighted-sum log-EDP across members."""

    def __init__(self, engine: CodesignEngine, portfolio: PortfolioConfig,
                 hw_callback=None):
        if engine.config.hw.prune != "off":
            raise ValueError(
                "portfolio search requires hw.prune='off': the EDP "
                "lower-bound gate censors against a summed-EDP incumbent, "
                "which is meaningless under the weighted member objective")
        if engine.strategy_name == "sequential":
            raise ValueError(
                "portfolio search cannot use the 'sequential' probe "
                "strategy (it stops at the first infeasible layer, leaving "
                "later members unevaluated); use make_portfolio_engine(), "
                "which upgrades it to the bit-identical 'layer_batched'")
        self.portfolio = portfolio
        self._member_layers = tuple(
            tuple(resolve_workload(w)) for w in portfolio.workloads)
        self._weights = portfolio.normalized_weights()
        self._front: list[tuple[tuple[float, ...], float]] = []
        union = [l for ls in self._member_layers for l in ls]
        super().__init__(engine, union, hw_callback=hw_callback)
        self.best["objective"] = -np.inf
        self.best["member_edps"] = None

    def _eval_hw(self, hw):
        engine, best = self.engine, self.best
        engine.strategy.evaluate_probe(engine, hw, engine.probe_seed(hw))
        member_edps: list[float] = []
        maps, per_layer = {}, {}
        for layers, w in zip(self._member_layers, self._weights):
            total = 0.0
            for layer in layers:
                m, edp = engine.cache.get((hw, layer), (None, float("inf")))
                if m is None:
                    if w > 0.0:
                        return None, False  # unknown-constraint violation
                    total = float("inf")
                    break
                total += edp
                maps[layer.name] = m
                per_layer[layer.name] = edp
            member_edps.append(total)
        # One-hot parity: the w > 0 filter keeps the sum a single
        # 1.0 * log10(edp) term, bitwise equal to the standalone utility
        # (and avoids 0 * log10(inf) = nan from zero-weight members).
        utility = -float(sum(w * np.log10(e)
                             for w, e in zip(self._weights, member_edps)
                             if w > 0.0))
        self._front.append((tuple(member_edps), utility))
        if utility > best["objective"]:
            best.update(edp=float(10.0 ** -utility), hw=hw, maps=maps,
                        per_layer=per_layer, objective=utility,
                        member_edps=tuple(member_edps))
        if engine.config.verbose:
            edps = ", ".join(f"{e:.3e}" for e in member_edps)
            print(f"  hw {hw.pe_mesh_x}x{hw.pe_mesh_y} -> member EDPs "
                  f"[{edps}]  weighted geomean {10.0 ** -utility:.3e}")
        return utility, True

    def _pareto_front(self) -> list[dict]:
        """Non-dominated per-member EDP vectors (positive-weight members,
        minimization) among all feasible scored probes, JSON-friendly."""
        pos = [i for i, w in enumerate(self._weights) if w > 0.0]
        names = [self.portfolio.workloads[i] for i in pos]
        pts: dict[tuple[float, ...], float] = {}
        for edps, utility in self._front:
            pts.setdefault(tuple(edps[i] for i in pos), utility)
        keys = list(pts)
        front = [
            v for v in keys
            if not any(o != v and all(a <= b for a, b in zip(o, v))
                       for o in keys)
        ]
        front.sort(key=lambda v: -pts[v])
        return [{"member_edps": dict(zip(names, v)), "objective": pts[v]}
                for v in front]

    def result(self) -> CoDesignResult:
        res = super().result()
        res.stats["portfolio_workloads"] = list(self.portfolio.workloads)
        res.stats["portfolio_weights"] = list(self._weights)
        res.stats["portfolio_member_edps"] = (
            dict(zip(self.portfolio.workloads, self.best["member_edps"]))
            if self.best["member_edps"] is not None else None)
        res.stats["portfolio_pareto"] = self._pareto_front()
        return res

    def snapshot(self) -> dict:
        snap = super().snapshot()
        snap["front"] = [[list(edps), utility] for edps, utility in self._front]
        return snap

    def restore(self, snap: dict) -> "PortfolioSession":
        super().restore(snap)
        self._front = [(tuple(edps), float(utility))
                       for edps, utility in snap.get("front", [])]
        return self


def make_portfolio_engine(config: CodesignConfig | None = None,
                          executor=None) -> CodesignEngine:
    """`CodesignEngine` prepared for portfolio search: validates
    `hw.prune == "off"` and upgrades a resolved "sequential" strategy to the
    bit-identical "layer_batched" (see module docstring)."""
    cfg = config if config is not None else CodesignConfig()
    if cfg.hw.prune != "off":
        raise ValueError(
            f"portfolio search requires hw.prune='off', got "
            f"{cfg.hw.prune!r}")
    if cfg.engine.resolve_strategy() == "sequential":
        cfg = dataclasses.replace(
            cfg, engine=dataclasses.replace(cfg.engine,
                                            strategy="layer_batched"))
    return CodesignEngine(cfg, executor=executor)


def portfolio_session(portfolio: PortfolioConfig,
                      config: CodesignConfig | None = None,
                      executor=None, hw_callback=None) -> PortfolioSession:
    engine = make_portfolio_engine(config, executor=executor)
    return PortfolioSession(engine, portfolio, hw_callback=hw_callback)


def portfolio_codesign(portfolio: PortfolioConfig,
                       config: CodesignConfig | None = None,
                       executor=None) -> CoDesignResult:
    """Run a portfolio search to completion (the stepwise form is
    `portfolio_session`)."""
    session = portfolio_session(portfolio, config, executor=executor)
    while session.step():
        pass
    return session.result()
