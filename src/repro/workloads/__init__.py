"""Workload zoo + portfolio co-design.

Two halves, layered ON the search core (repro.core is untouched):

- `zoo`: converts any `ModelConfig` in `repro.configs` into a named
  `ConvLayer` workload set (attention projections, MoE expert FFNs, recurrent
  gate matmuls, the rglru temporal conv) via a per-block-kind extractor
  registry, MACs-cross-checked against `repro.models.flops.forward_flops`.
- `portfolio`: one hardware config scored against a weighted mix of workload
  sets -- each outer trial fans the union of all members' layers into ONE
  stacked inner dispatch, scored by weighted-sum log-EDP, Pareto front in
  `CoDesignResult.stats`.
"""

from repro.workloads.portfolio import (PortfolioConfig, PortfolioSession,
                                       make_portfolio_engine,
                                       portfolio_codesign, portfolio_session)
from repro.workloads.zoo import (MACS_RTOL, ZOO_NAMES, ZooWorkload,
                                 known_workloads, resolve_workload,
                                 workload_set, zoo_workload)

__all__ = [
    "MACS_RTOL",
    "ZOO_NAMES",
    "ZooWorkload",
    "known_workloads",
    "resolve_workload",
    "workload_set",
    "zoo_workload",
    "PortfolioConfig",
    "PortfolioSession",
    "make_portfolio_engine",
    "portfolio_codesign",
    "portfolio_session",
]
