"""Sharded checkpointing: per-leaf .npy files + JSON manifest, step-tagged
directories, atomic latest-pointer, optional async writer thread.

Layout:
    <dir>/step_000123/manifest.json
    <dir>/step_000123/leaf_00000.npy ...
    <dir>/LATEST                      (atomic rename -> crash-safe pointer)

On a real multi-host cluster each host writes only the shards it owns (the
`process_index` filter below); on one host it degenerates to a full save.

Concurrent saves into one directory are safe: each save stages into a unique
temp directory (never a shared `<step>.tmp` name two writers would collide
on), publishes the step directory and the LATEST pointer with `os.replace`
under a per-directory lock, and LATEST only ever moves forward -- a slow
writer finishing an old step cannot point LATEST at it after a newer step
landed.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading

import jax
import numpy as np

# Serializes the publish step (step-dir + LATEST rename) across threads of
# this process; cross-process writers are already safe through os.replace,
# the lock additionally keeps LATEST monotone among our own threads.
_publish_lock = threading.Lock()


def _leaf_paths(tree):
    paths_leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in paths_leaves:
        p = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out.append((p, leaf))
    return out


def save(directory: str, step: int, state) -> str:
    """Synchronous checkpoint save; returns the step directory."""
    os.makedirs(directory, exist_ok=True)
    step_dir = os.path.join(directory, f"step_{step:08d}")
    # Unique staging dir per save call: concurrent saves of the SAME step
    # (async writer + a late sync save, or two engines sharing a directory)
    # must not interleave writes into one tmp dir.
    tmp_dir = tempfile.mkdtemp(prefix=f"step_{step:08d}.tmp.", dir=directory)
    try:
        manifest = {"step": step, "leaves": []}
        for i, (path, leaf) in enumerate(_leaf_paths(state)):
            arr = np.asarray(jax.device_get(leaf))
            fname = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp_dir, fname), arr)
            manifest["leaves"].append(
                {"path": path, "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)})
        with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with _publish_lock:
            if os.path.exists(step_dir):
                shutil.rmtree(step_dir)
            os.rename(tmp_dir, step_dir)
            current = latest_step(directory)
            if current is None or step >= current:  # LATEST is monotone
                fd, latest_tmp = tempfile.mkstemp(
                    prefix="LATEST.tmp.", dir=directory)
                with os.fdopen(fd, "w") as f:
                    f.write(os.path.basename(step_dir))
                os.replace(latest_tmp, os.path.join(directory, "LATEST"))
    except BaseException:
        shutil.rmtree(tmp_dir, ignore_errors=True)
        raise
    return step_dir


def latest_step(directory: str) -> int | None:
    pointer = os.path.join(directory, "LATEST")
    if not os.path.exists(pointer):
        return None
    with open(pointer) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(directory, name)):
        return None
    return int(name.split("_")[-1])


def restore(directory: str, like, step: int | None = None):
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs).  Returns (state, step)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    step_dir = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in paths_leaves:
        p = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        entry = by_path[p]
        arr = np.load(os.path.join(step_dir, entry["file"]))
        assert tuple(arr.shape) == tuple(leaf.shape), (p, arr.shape, leaf.shape)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), step


class AsyncCheckpointer:
    """Fire-and-forget saves on a writer thread; at most one in flight
    (training never blocks on I/O unless a save is already running).

    Use as a context manager (or call `close()`): the writer thread is
    non-daemon work in flight, and `close()` joins it so process exit never
    truncates a checkpoint mid-write.  A save that raised on the thread
    re-raises from the next `save()`/`wait()`/`close()` call instead of
    vanishing."""

    def __init__(self, directory: str):
        self.directory = directory
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None
        self.last_saved: int | None = None

    def save(self, step: int, state):
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)

        def work():
            try:
                save(self.directory, step, host_state)
                self.last_saved = step
            except BaseException as e:  # surfaced by the next wait()
                self._error = e

        self._thread = threading.Thread(target=work)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            error, self._error = self._error, None
            raise error

    def close(self):
        """Join any in-flight save; the checkpointer stays usable after."""
        self.wait()

    def __enter__(self) -> "AsyncCheckpointer":
        return self

    def __exit__(self, *exc) -> None:
        # Don't mask an exception already unwinding with a writer error.
        if exc[0] is None:
            self.close()
        else:
            if self._thread is not None:
                self._thread.join()
                self._thread = None
