"""Sharded checkpointing: per-leaf .npy files + JSON manifest, step-tagged
directories, atomic latest-pointer, optional async writer thread.

Layout:
    <dir>/step_000123/manifest.json
    <dir>/step_000123/leaf_00000.npy ...
    <dir>/LATEST                      (atomic rename -> crash-safe pointer)

On a real multi-host cluster each host writes only the shards it owns (the
`process_index` filter below); on one host it degenerates to a full save.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _leaf_paths(tree):
    paths_leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in paths_leaves:
        p = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out.append((p, leaf))
    return out


def save(directory: str, step: int, state) -> str:
    """Synchronous checkpoint save; returns the step directory."""
    step_dir = os.path.join(directory, f"step_{step:08d}")
    tmp_dir = step_dir + ".tmp"
    os.makedirs(tmp_dir, exist_ok=True)
    manifest = {"step": step, "leaves": []}
    for i, (path, leaf) in enumerate(_leaf_paths(state)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp_dir, fname), arr)
        manifest["leaves"].append(
            {"path": path, "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)})
    with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)
    os.rename(tmp_dir, step_dir)
    latest_tmp = os.path.join(directory, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(os.path.basename(step_dir))
    os.rename(latest_tmp, os.path.join(directory, "LATEST"))  # atomic pointer
    return step_dir


def latest_step(directory: str) -> int | None:
    pointer = os.path.join(directory, "LATEST")
    if not os.path.exists(pointer):
        return None
    with open(pointer) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(directory, name)):
        return None
    return int(name.split("_")[-1])


def restore(directory: str, like, step: int | None = None):
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs).  Returns (state, step)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    step_dir = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in paths_leaves:
        p = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        entry = by_path[p]
        arr = np.load(os.path.join(step_dir, entry["file"]))
        assert tuple(arr.shape) == tuple(leaf.shape), (p, arr.shape, leaf.shape)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), step


class AsyncCheckpointer:
    """Fire-and-forget saves on a writer thread; at most one in flight
    (training never blocks on I/O unless a save is already running)."""

    def __init__(self, directory: str):
        self.directory = directory
        self._thread: threading.Thread | None = None
        self.last_saved: int | None = None

    def save(self, step: int, state):
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)

        def work():
            save(self.directory, step, host_state)
            self.last_saved = step

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
