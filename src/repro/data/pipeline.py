"""Deterministic synthetic token pipeline with per-host sharding and
double-buffered prefetch.

Real deployments swap `SyntheticSource` for a file-backed source; the iterator
contract (`next() -> {tokens, labels, ...}` numpy dict) and the prefetch/shard
machinery stay the same.  Data order is a pure function of (seed, step), which
is what makes checkpoint-restart exactly reproducible: resuming at step k
replays the same batch k.
"""

from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0


class SyntheticSource:
    """Markov-chain token stream: deterministic, seeded, non-trivial statistics
    (so losses actually decrease during the examples' training runs)."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, data: DataConfig):
        self.cfg, self.shape, self.data = cfg, shape, data
        self.V = cfg.vocab_size
        rng = np.random.default_rng(data.seed)
        k = 97  # latent states
        self._emit = rng.integers(0, self.V, size=(k,), dtype=np.int32)
        self._trans = rng.integers(0, k, size=(k, 7), dtype=np.int32)

    def batch(self, step: int) -> dict:
        cfg, shape, data = self.cfg, self.shape, self.data
        B = shape.global_batch // data.num_hosts
        S = shape.seq_len
        rng = np.random.default_rng(
            (data.seed * 1_000_003 + step) * 131 + data.host_id)
        state = rng.integers(0, self._trans.shape[0], size=(B,))
        toks = np.empty((B, S + 1), np.int32)
        for t in range(S + 1):
            toks[:, t] = self._emit[state]
            state = self._trans[state, rng.integers(0, 7, size=(B,))]
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.input_mode == "embeddings":
            emb_rng = np.random.default_rng(data.seed * 7 + step)
            batch["embeddings"] = emb_rng.standard_normal(
                (B, S, cfg.d_model), dtype=np.float32)
            del batch["tokens"]
        if cfg.family == "encdec":
            emb_rng = np.random.default_rng(data.seed * 13 + step)
            batch["src_embeddings"] = emb_rng.standard_normal(
                (B, max(S // 8, 16), cfg.d_model), dtype=np.float32)
            batch["tokens"] = toks[:, :-1]
        return batch


class Prefetcher:
    """Background-thread double buffering over any `batch(step)` source."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self):
        step, batch = self._q.get()
        return step, batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
