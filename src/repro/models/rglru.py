"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

The Real-Gated Linear Recurrent Unit:
    r_t = sigmoid(W_a u_t)            recurrence gate
    i_t = sigmoid(W_x u_t)            input gate
    a_t = exp(-c * softplus(Lambda) * r_t)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

Training/prefill uses `jax.lax.associative_scan` (log-depth on TPU) over the
linear recurrence; decode keeps (conv window, h) as O(1) state.  The block is
the Griffin recurrent mixer: linear in, depthwise causal conv(4), RG-LRU,
GeGLU-style output gating, linear out.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, rmsnorm, split_keys
from repro.models.xlstm import _causal_conv
from repro.parallel import sharding

_C = 8.0


def init_rglru_block(key, cfg: ModelConfig, dtype):
    D = cfg.d_model
    ks = split_keys(key, 6)
    return {
        "ln": jnp.zeros((D,), dtype),
        "w_x": dense_init(ks[0], (D, D), dtype),
        "w_gate": dense_init(ks[1], (D, D), dtype),
        "conv_w": dense_init(ks[2], (cfg.rglru_conv_width, D), dtype, scale=0.5),
        "w_a": dense_init(ks[3], (D, D), dtype, scale=0.01),
        "w_i": dense_init(ks[4], (D, D), dtype, scale=0.01),
        # Lambda init so a^c in (0.9, 0.999) at r=1 (paper's init range)
        "lam": jnp.linspace(2.0, 6.0, D).astype(dtype),
        "w_o": dense_init(ks[5], (D, D), dtype, scale=D ** -0.5),
    }


def _gates(p, u):
    r = jax.nn.sigmoid((u @ p["w_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid((u @ p["w_i"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * i * u.astype(jnp.float32)
    return a, b


def rglru_block(p, cfg: ModelConfig, x, state=None, return_state=False):
    """x: (B,S,D) -> delta (B,S,D)."""
    h = rmsnorm(x, p["ln"])
    u = h @ p["w_x"]
    g = jax.nn.gelu(h @ p["w_gate"])
    u_raw = u
    decode = state is not None and x.shape[1] == 1
    if decode:
        u, new_conv = _causal_conv(u, p["conv_w"], state["conv"].astype(u.dtype))
        a, b = _gates(p, u)
        hh = a[:, 0] * state["h"] + b[:, 0]
        out_h = hh[:, None]
        new_state = {"conv": new_conv.astype(jnp.float32), "h": hh}
    else:
        u, _ = _causal_conv(u, p["conv_w"])
        a, b = _gates(p, u)
        if state is not None:  # fold initial state into the first step
            b = b.at[:, 0].add(a[:, 0] * state["h"])
        _, bb = jax.lax.associative_scan(
            lambda l, r: (l[0] * r[0], r[0] * l[1] + r[1]), (a, b), axis=1
        )
        out_h = bb
        w1 = cfg.rglru_conv_width - 1
        new_state = {"conv": u_raw[:, -w1:].astype(jnp.float32), "h": bb[:, -1]}
    out = (out_h * g.astype(jnp.float32)).astype(x.dtype) @ p["w_o"]
    out = sharding.act(out, "batch", "seq", "dmodel")
    if return_state:
        return out, new_state
    return out


def init_rglru_state(cfg: ModelConfig, batch: int):
    return {
        "conv": jnp.zeros((batch, cfg.rglru_conv_width - 1, cfg.d_model), jnp.float32),
        "h": jnp.zeros((batch, cfg.d_model), jnp.float32),
    }


def rglru_block_decode(p, cfg: ModelConfig, x, state):
    return rglru_block(p, cfg, x, state=state, return_state=True)
