"""Model facade: build any assigned architecture and produce step functions +
ShapeDtypeStruct input specs for every (shape x kind) cell."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.encdec import EncDecLM
from repro.models.lm import LM


def build_model(cfg: ModelConfig):
    return EncDecLM(cfg) if cfg.family == "encdec" else LM(cfg)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for the step function's *data* arguments.
    Modality frontends are stubs: embeddings arrive precomputed (assignment)."""
    B, S = shape.global_batch, shape.seq_len
    specs: dict = {}
    if cfg.family == "encdec":
        S_src = max(S // 8, 16)
        specs["src_embeddings"] = _sds((B, S_src, cfg.d_model), cfg.compute_dtype)
        if shape.kind == "decode":
            specs["tokens"] = _sds((B, 1), "int32")
        else:
            specs["tokens"] = _sds((B, S), "int32")
            if shape.kind == "train":
                specs["labels"] = _sds((B, S), "int32")
        return specs

    if shape.kind == "decode":
        if cfg.input_mode == "embeddings":
            specs["embeddings"] = _sds((B, 1, cfg.d_model), cfg.compute_dtype)
        else:
            specs["tokens"] = _sds((B, 1), "int32")
        return specs

    if cfg.input_mode == "embeddings":
        specs["embeddings"] = _sds((B, S, cfg.d_model), cfg.compute_dtype)
    else:
        specs["tokens"] = _sds((B, S), "int32")
    if cfg.mrope:
        specs["positions"] = _sds((3, B, S), "int32")
    if shape.kind == "train":
        specs["labels"] = _sds((B, S), "int32")
    return specs


def cache_specs(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStructs for the decode cache (incl. enc-dec encoder output)."""
    model = build_model(cfg)
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        S_src = max(S // 8, 16)

        def mk():
            cache = model.init_cache(B, S)
            enc = jnp.zeros((B, S_src, cfg.d_model), jnp.dtype(cfg.compute_dtype))
            return (cache, enc)

        return jax.eval_shape(mk)
    return jax.eval_shape(lambda: model.init_cache(B, S))
