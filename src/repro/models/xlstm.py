"""xLSTM blocks (Beck et al., arXiv:2405.04517): mLSTM and sLSTM.

mLSTM (matrix memory, exponential gating) is computed in the TPU-native
*chunkwise-parallel* form: quadratic attention-like compute inside fixed-size
chunks, a recurrent (C, n, m)-state scan across chunks -- linear memory in
sequence length, MXU-friendly matmuls inside chunks.  Decode uses the O(1)
recurrent update.  sLSTM has true recurrence (hidden-state feedback into the
gates), so training scans over time steps.

Both are validated against naive per-timestep references in tests/.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (analysis_unroll, dense_init, head_rmsnorm,
                                 rmsnorm, split_keys)
from repro.parallel import sharding


# ------------------------------------------------------------- mLSTM core math

def mlstm_chunkwise(q, k, v, ig, fg, chunk: int, state=None):
    """q,k,v: (B,S,H,dh); ig,fg: (B,S,H) raw gate pre-activations.
    Returns (out (B,S,H,dh), final_state (C,n,m))."""
    B, S, H, dh = q.shape
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    scale = dh ** -0.5

    def to_chunks(x):
        return x.reshape(B, nc, chunk, *x.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = to_chunks(q * scale), to_chunks(k), to_chunks(v)
    igc, fgc = to_chunks(ig), to_chunks(fg)  # (nc, B, L, H)

    if state is None:
        C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
        n0 = jnp.zeros((B, H, dh), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
        state = (C0, n0, m0)

    def chunk_step(carry, xs):
        C, n, m = carry
        qq, kk, vv, ii, ff = xs  # (B,L,H,dh) / (B,L,H)
        qq = qq.astype(jnp.float32)
        kk = kk.astype(jnp.float32)
        vv = vv.astype(jnp.float32)
        logf = jax.nn.log_sigmoid(ff.astype(jnp.float32))   # (B,L,H)
        ii = ii.astype(jnp.float32)
        F = jnp.cumsum(logf, axis=1)                        # (B,L,H)
        Ftot = F[:, -1]                                     # (B,H)

        # Stabilizers.
        g_intra = F[:, :, None, :] - F[:, None, :, :] + ii[:, None, :, :]  # (B,t,s,H)
        L = qq.shape[1]
        tri = (jnp.arange(L)[:, None] >= jnp.arange(L)[None, :])[None, :, :, None]
        g_intra = jnp.where(tri, g_intra, -jnp.inf)
        m_intra = jnp.max(g_intra, axis=2)                   # (B,t,H)
        m_inter = F + m[:, None, :]                          # (B,t,H)
        m_t = jnp.maximum(m_intra, m_inter)                  # (B,t,H)
        m_t = jnp.maximum(m_t, -1e30)

        D = jnp.exp(g_intra - m_t[:, :, None, :])            # (B,t,s,H)
        D = jnp.where(tri, D, 0.0)
        scores = jnp.einsum("bthd,bshd->btsh", qq, kk) * D   # (B,t,s,H)
        intra = jnp.einsum("btsh,bshd->bthd", scores, vv)
        inter_w = jnp.exp(m_inter - m_t)                     # (B,t,H)
        inter = jnp.einsum("bthd,bhde->bthe", qq, C) * inter_w[..., None]
        num = intra + inter

        l_intra = jnp.sum(scores, axis=2)                    # (B,t,H)
        l_inter = jnp.einsum("bthd,bhd->bth", qq, n) * inter_w
        denom = jnp.maximum(jnp.abs(l_intra + l_inter), jnp.exp(-m_t)) + 1e-6
        out = num / denom[..., None]

        # State update to the end of the chunk.
        g_state = Ftot[:, None, :] - F + ii                  # (B,s,H)
        m_new = jnp.maximum(Ftot + m, jnp.max(g_state, axis=1))
        w_old = jnp.exp(Ftot + m - m_new)                    # (B,H)
        w_s = jnp.exp(g_state - m_new[:, None, :])           # (B,s,H)
        C_new = C * w_old[:, :, None, None] + jnp.einsum(
            "bshd,bshe,bsh->bhde", kk, vv, w_s)
        n_new = n * w_old[..., None] + jnp.einsum("bshd,bsh->bhd", kk, w_s)
        return (C_new, n_new, m_new), out

    state, outs = jax.lax.scan(chunk_step, state, (qc, kc, vc, igc, fgc), unroll=analysis_unroll(nc))
    out = outs.swapaxes(0, 1).reshape(B, S, H, dh)
    return out, state


def mlstm_recurrent_step(q, k, v, ig, fg, state):
    """One-token recurrent update. q,k,v: (B,H,dh); ig,fg: (B,H)."""
    C, n, m = state
    q = q.astype(jnp.float32) * (q.shape[-1] ** -0.5)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    logf = jax.nn.log_sigmoid(fg.astype(jnp.float32))
    ii = ig.astype(jnp.float32)
    m_new = jnp.maximum(logf + m, ii)
    fw = jnp.exp(logf + m - m_new)
    iw = jnp.exp(ii - m_new)
    C = C * fw[..., None, None] + jnp.einsum("bhd,bhe,bh->bhde", k, v, iw)
    n = n * fw[..., None] + k * iw[..., None]
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)), jnp.exp(-m_new)) + 1e-6
    return num / denom[..., None], (C, n, m_new)


# ------------------------------------------------------------------ mLSTM block

def _causal_conv(x, w, state=None):
    """Depthwise causal conv. x: (B,S,Dn), w: (width, Dn).
    With `state` (B,width-1,Dn): single-step mode (S==1)."""
    width = w.shape[0]
    if state is not None:
        window = jnp.concatenate([state, x], axis=1)         # (B,width,Dn)
        out = jnp.einsum("bwd,wd->bd", window, w)[:, None]
        return out, window[:, 1:]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + x.shape[1]] * w[i] for i in range(width))
    return out, None


def init_mlstm_block(key, cfg: ModelConfig, dtype):
    D = cfg.d_model
    Din = 2 * D
    H = cfg.num_heads
    dh = Din // H
    ks = split_keys(key, 9)
    return {
        "ln": jnp.zeros((D,), dtype),
        "w_up": dense_init(ks[0], (D, Din), dtype),
        "w_gate_up": dense_init(ks[1], (D, Din), dtype),
        "conv_w": dense_init(ks[2], (4, Din), dtype, scale=0.5),
        # block-diagonal per-head q/k/v projections
        "wq": dense_init(ks[3], (H, dh, dh), dtype, scale=dh ** -0.5),
        "wk": dense_init(ks[4], (H, dh, dh), dtype, scale=dh ** -0.5),
        "wv": dense_init(ks[5], (H, dh, dh), dtype, scale=dh ** -0.5),
        "w_ig": dense_init(ks[6], (Din, H), dtype, scale=0.01),
        "w_fg": dense_init(ks[7], (Din, H), dtype, scale=0.01),
        "b_fg": jnp.full((H,), 3.0, dtype),  # forget-gate bias: remember by default
        "gn": jnp.zeros((H, dh), dtype),
        "w_down": dense_init(ks[8], (Din, D), dtype, scale=Din ** -0.5),
    }


def _mlstm_qkvg(p, cfg, u_conv, u):
    B, S, Din = u.shape
    H = cfg.num_heads
    dh = Din // H
    ch = u_conv.reshape(B, S, H, dh)
    uh = u.reshape(B, S, H, dh)
    q = jnp.einsum("bshd,hde->bshe", ch, p["wq"])
    k = jnp.einsum("bshd,hde->bshe", ch, p["wk"])
    v = jnp.einsum("bshd,hde->bshe", uh, p["wv"])
    ig = u_conv @ p["w_ig"]
    fg = u_conv @ p["w_fg"] + p["b_fg"]
    return q, k, v, ig, fg


def mlstm_block(p, cfg: ModelConfig, x):
    B, S, D = x.shape
    h = rmsnorm(x, p["ln"])
    u = h @ p["w_up"]
    g = h @ p["w_gate_up"]
    u = sharding.act(u, "batch", "seq", "ff")
    uc, _ = _causal_conv(u, p["conv_w"])
    uc = jax.nn.silu(uc)
    q, k, v, ig, fg = _mlstm_qkvg(p, cfg, uc, u)
    out, _ = mlstm_chunkwise(q, k, v, ig, fg, cfg.mlstm_chunk)
    out = head_rmsnorm(out, p["gn"])
    out = out.reshape(B, S, -1) * jax.nn.silu(g)
    out = out.astype(x.dtype) @ p["w_down"]
    return sharding.act(out, "batch", "seq", "dmodel")


def mlstm_block_prefill(p, cfg: ModelConfig, x):
    """Full-sequence mLSTM that also emits the recurrent decode state."""
    B, S, D = x.shape
    h = rmsnorm(x, p["ln"])
    u = h @ p["w_up"]
    g = h @ p["w_gate_up"]
    u = sharding.act(u, "batch", "seq", "ff")
    uc, _ = _causal_conv(u, p["conv_w"])
    uc = jax.nn.silu(uc)
    q, k, v, ig, fg = _mlstm_qkvg(p, cfg, uc, u)
    out, (C, n, m) = mlstm_chunkwise(q, k, v, ig, fg, cfg.mlstm_chunk)
    out = head_rmsnorm(out, p["gn"])
    out = out.reshape(B, S, -1) * jax.nn.silu(g)
    out = out.astype(x.dtype) @ p["w_down"]
    out = sharding.act(out, "batch", "seq", "dmodel")
    state = {"conv": u[:, -3:].astype(jnp.float32), "C": C, "n": n, "m": m}
    return out, state


def init_mlstm_state(cfg: ModelConfig, batch: int):
    Din = 2 * cfg.d_model
    H = cfg.num_heads
    dh = Din // H
    return {
        "conv": jnp.zeros((batch, 3, Din), jnp.float32),
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


def mlstm_block_decode(p, cfg: ModelConfig, x, state):
    """x: (B,1,D)."""
    B = x.shape[0]
    h = rmsnorm(x, p["ln"])
    u = h @ p["w_up"]
    g = h @ p["w_gate_up"]
    uc, conv_state = _causal_conv(u, p["conv_w"], state["conv"].astype(u.dtype))
    uc = jax.nn.silu(uc)
    q, k, v, ig, fg = _mlstm_qkvg(p, cfg, uc, u)
    out, (C, n, m) = mlstm_recurrent_step(
        q[:, 0], k[:, 0], v[:, 0], ig[:, 0], fg[:, 0], (state["C"], state["n"], state["m"])
    )
    out = head_rmsnorm(out[:, None], p["gn"])  # (B,1,H,dh)
    out = out.reshape(B, 1, -1) * jax.nn.silu(g)
    out = out.astype(x.dtype) @ p["w_down"]
    new_state = {"conv": conv_state.astype(jnp.float32), "C": C, "n": n, "m": m}
    return out, new_state


# ------------------------------------------------------------------ sLSTM block

def init_slstm_block(key, cfg: ModelConfig, dtype):
    D = cfg.d_model
    H = cfg.num_heads
    dh = D // H
    F = (4 * D) // 3
    F = ((F + 63) // 64) * 64  # round for shardability
    ks = split_keys(key, 11)
    p = {"ln": jnp.zeros((D,), dtype)}
    for i, gate in enumerate(("i", "f", "z", "o")):
        p[f"w_{gate}"] = dense_init(ks[i], (D, D), dtype)
        p[f"r_{gate}"] = dense_init(ks[4 + i], (H, dh, dh), dtype, scale=dh ** -0.5)
        p[f"b_{gate}"] = (jnp.full((D,), 1.0, dtype) if gate == "f" else jnp.zeros((D,), dtype))
    p["gn"] = jnp.zeros((H, dh), dtype)
    p["ffn_up"] = dense_init(ks[8], (D, 2 * F), dtype)
    p["ffn_down"] = dense_init(ks[9], (F, D), dtype, scale=F ** -0.5)
    p["w_out"] = dense_init(ks[10], (D, D), dtype, scale=D ** -0.5)
    return p


def _slstm_step(p, cfg, carry, gates_x):
    """carry: dict(h,c,n,m) each (B,H,dh); gates_x: dict of (B,D) pre-activations."""
    B = carry["h"].shape[0]
    H = cfg.num_heads
    dh = cfg.d_model // H

    def rec(gate):
        return (gates_x[gate].reshape(B, H, dh)
                + jnp.einsum("bhd,hde->bhe", carry["h"], p[f"r_{gate}"]).astype(jnp.float32))

    it, ft, zt, ot = rec("i"), rec("f"), rec("z"), rec("o")
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + carry["m"], it)
    iw = jnp.exp(it - m_new)
    fw = jnp.exp(logf + carry["m"] - m_new)
    c = fw * carry["c"] + iw * jnp.tanh(zt)
    n = fw * carry["n"] + iw
    h = jax.nn.sigmoid(ot) * c / jnp.maximum(n, 1e-6)
    return {"h": h, "c": c, "n": n, "m": m_new}


def init_slstm_state(cfg: ModelConfig, batch: int):
    H = cfg.num_heads
    dh = cfg.d_model // H
    z = jnp.zeros((batch, H, dh), jnp.float32)
    return {"h": z, "c": z, "n": z, "m": jnp.full((batch, H, dh), -1e30, jnp.float32)}


def slstm_block(p, cfg: ModelConfig, x, state=None, return_state=False):
    B, S, D = x.shape
    hln = rmsnorm(x, p["ln"])
    gates = {g: (hln @ p[f"w_{g}"] + p[f"b_{g}"]).astype(jnp.float32) for g in "ifzo"}
    carry = state if state is not None else init_slstm_state(cfg, B)

    def step(c, xs):
        new = _slstm_step(p, cfg, c, xs)
        return new, new["h"]

    carry, hs = jax.lax.scan(step, carry, jax.tree.map(lambda a: a.swapaxes(0, 1), gates))
    hs = hs.swapaxes(0, 1).reshape(B, S, cfg.num_heads, -1)   # (B,S,H,dh)
    out = head_rmsnorm(hs, p["gn"]).reshape(B, S, D).astype(x.dtype)
    out = out @ p["w_out"]
    # post-up-projection FFN (GeGLU 4/3)
    y = out + x
    gu = rmsnorm(y, p["ln"]) @ p["ffn_up"]
    a, b = jnp.split(gu, 2, axis=-1)
    ffn = (jax.nn.gelu(a) * b) @ p["ffn_down"]
    res = out + ffn
    res = sharding.act(res, "batch", "seq", "dmodel")
    if return_state:
        return res, carry
    return res


def slstm_block_decode(p, cfg: ModelConfig, x, state):
    out, new_state = slstm_block(p, cfg, x, state=state, return_state=True)
    return out, new_state
