"""Decoder-only LM over heterogeneous block patterns.

Layers are grouped into "super-blocks" of one block-pattern period; parameters
are stacked over super-blocks and the stack is traversed with `jax.lax.scan`
(constant compile time in depth -- required for 80-layer dry-runs and correct
for production).  Remat ("block") checkpoints each super-block.

Block kinds: attn | local_attn | moe | mlstm | slstm | rglru.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import rglru as RG
from repro.models import xlstm as XL
from repro.parallel import sharding


def _dtype(name):
    return jnp.dtype(name)


# ------------------------------------------------------------ per-kind dispatch

def init_block(kind: str, key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    if kind in ("attn", "local_attn"):
        p = {"attn": L.init_attention(k1, cfg, dtype)}
        if cfg.d_ff > 0:
            p["mlp"] = L.init_mlp(k2, cfg, dtype)
        return p
    if kind == "moe":
        return {"attn": L.init_attention(k1, cfg, dtype),
                "moe": MOE.init_moe(k2, cfg, dtype)}
    if kind == "mlstm":
        return {"mlstm": XL.init_mlstm_block(k1, cfg, dtype)}
    if kind == "slstm":
        return {"slstm": XL.init_slstm_block(k1, cfg, dtype)}
    if kind == "rglru":
        p = {"rglru": RG.init_rglru_block(k1, cfg, dtype)}
        if cfg.d_ff > 0:
            p["mlp"] = L.init_mlp(k2, cfg, dtype)
        return p
    raise ValueError(kind)


def apply_block(kind: str, p, cfg: ModelConfig, x, positions):
    window = cfg.local_window if kind == "local_attn" else 0
    if kind in ("attn", "local_attn"):
        x = x + L.attention(p["attn"], cfg, x, positions, window)
        if "mlp" in p:
            x = x + L.mlp(p["mlp"], x)
        return x
    if kind == "moe":
        x = x + L.attention(p["attn"], cfg, x, positions, 0)
        return x + MOE.moe_block(p["moe"], cfg, x)
    if kind == "mlstm":
        return x + XL.mlstm_block(p["mlstm"], cfg, x)
    if kind == "slstm":
        return x + XL.slstm_block(p["slstm"], cfg, x)
    if kind == "rglru":
        x = x + RG.rglru_block(p["rglru"], cfg, x)
        if "mlp" in p:
            x = x + L.mlp(p["mlp"], x)
        return x
    raise ValueError(kind)


def init_block_cache(kind: str, cfg: ModelConfig, batch: int, spec: L.CacheSpec):
    if kind in ("attn", "moe"):
        return L.init_kv_cache(cfg, batch, spec)
    if kind == "local_attn":
        # Rolling-window cache: only local_window slots, plus absolute pos ids.
        W = min(cfg.local_window or spec.seq_len, spec.seq_len)
        c = L.init_kv_cache(cfg, batch, L.CacheSpec(W, spec.dtype))
        c["pos_ids"] = jnp.full((W,), -1, jnp.int32)
        return c
    if kind == "mlstm":
        return XL.init_mlstm_state(cfg, batch)
    if kind == "slstm":
        return XL.init_slstm_state(cfg, batch)
    if kind == "rglru":
        return RG.init_rglru_state(cfg, batch)
    raise ValueError(kind)


def apply_block_decode(kind: str, p, cfg: ModelConfig, x, cache, pos):
    if kind in ("attn", "local_attn", "moe"):
        if kind == "local_attn":
            delta, cache = L.attention_decode_windowed(p["attn"], cfg, x, cache, pos)
        else:
            delta, cache = L.attention_decode(p["attn"], cfg, x, cache, pos, 0)
        x = x + delta
        if kind == "moe":
            x = x + MOE.moe_block(p["moe"], cfg, x)
        elif "mlp" in p:
            x = x + L.mlp(p["mlp"], x)
        return x, cache
    if kind == "mlstm":
        delta, st = XL.mlstm_block_decode(p["mlstm"], cfg, x, cache)
        return x + delta, st
    if kind == "slstm":
        delta, st = XL.slstm_block_decode(p["slstm"], cfg, x, cache)
        return x + delta, st
    if kind == "rglru":
        delta, st = RG.rglru_block_decode(p["rglru"], cfg, x, cache)
        x = x + delta
        if "mlp" in p:
            x = x + L.mlp(p["mlp"], x)
        return x, cache if st is None else st
    raise ValueError(kind)


# ----------------------------------------------------------------------- model

class LM:
    """Functional decoder-only LM; all methods are pure and jit-friendly."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.pattern = cfg.block_pattern
        self.n_super = cfg.num_layers // len(self.pattern)

    # -- params -----------------------------------------------------------

    def init(self, key):
        cfg = self.cfg
        dtype = _dtype(cfg.param_dtype)
        k_embed, k_blocks = jax.random.split(key)
        params = {"embed": L.init_embed(k_embed, cfg, dtype),
                  "final_ln": jnp.zeros((cfg.d_model,), dtype)}
        if cfg.input_mode == "embeddings":
            params["in_proj"] = L.dense_init(jax.random.fold_in(k_embed, 1),
                                             (cfg.d_model, cfg.d_model), dtype)

        def init_super(k):
            ks = jax.random.split(k, len(self.pattern))
            return {f"pos{i}": init_block(kind, ks[i], cfg, dtype)
                    for i, kind in enumerate(self.pattern)}

        keys = jax.random.split(k_blocks, self.n_super)
        params["blocks"] = jax.vmap(init_super)(keys)
        return params

    def param_shapes(self):
        return jax.eval_shape(lambda k: self.init(k), jax.random.key(0))

    # -- shared forward ----------------------------------------------------

    def _inputs(self, params, batch):
        cfg = self.cfg
        cdt = _dtype(cfg.compute_dtype)
        if cfg.input_mode == "embeddings":
            x = batch["embeddings"].astype(cdt) @ params["in_proj"].astype(cdt)
        else:
            x = L.embed(params["embed"], batch["tokens"]).astype(cdt)
        B, S = x.shape[:2]
        if cfg.mrope:
            positions = batch.get("positions")
            if positions is None:
                positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
                positions = jnp.broadcast_to(positions[None], (3, B, S))
        else:
            positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        return x, positions

    def _cast(self, params):
        cdt = _dtype(self.cfg.compute_dtype)
        return jax.tree.map(lambda a: a.astype(cdt) if a.dtype in
                            (jnp.float32, jnp.bfloat16, jnp.float16) else a, params)

    def _backbone(self, params, x, positions):
        cfg = self.cfg
        pattern = self.pattern

        def body(h, pslice):
            for i, kind in enumerate(pattern):
                h = apply_block(kind, pslice[f"pos{i}"], cfg, h, positions)
            h = sharding.act(h, "batch", "seq", "dmodel")
            return h, None

        if cfg.remat == "block":
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(body, x, params["blocks"], unroll=L.analysis_unroll(self.n_super))
        return L.rmsnorm(x, params["final_ln"])

    # -- train ---------------------------------------------------------------

    def loss(self, params, batch):
        params = self._cast(params)
        x, positions = self._inputs(params, batch)
        x = self._backbone(params, x, positions)
        return L.softmax_xent(params["embed"], x, batch["labels"], self.cfg.vocab_size)

    # -- serve -----------------------------------------------------------------

    def cache_spec(self, seq_len: int) -> L.CacheSpec:
        return L.CacheSpec(seq_len, self.cfg.kv_cache_dtype)

    def init_cache(self, batch: int, seq_len: int):
        spec = self.cache_spec(seq_len)

        def one(_):
            return {f"pos{i}": init_block_cache(kind, self.cfg, batch, spec)
                    for i, kind in enumerate(self.pattern)}

        return jax.vmap(one)(jnp.arange(self.n_super))

    def decode_step(self, params, cache, batch, pos):
        """batch: {"tokens": (B,1)} or {"embeddings": (B,1,D)}; pos scalar."""
        params = self._cast(params)
        cfg = self.cfg
        cdt = _dtype(cfg.compute_dtype)
        if cfg.input_mode == "embeddings":
            x = batch["embeddings"].astype(cdt) @ params["in_proj"]
        else:
            x = L.embed(params["embed"], batch["tokens"]).astype(cdt)

        pattern = self.pattern

        def body(h, xs):
            pslice, cslice = xs
            new_c = {}
            for i, kind in enumerate(pattern):
                h, new_c[f"pos{i}"] = apply_block_decode(
                    kind, pslice[f"pos{i}"], cfg, h, cslice[f"pos{i}"], pos)
            return h, new_c

        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache), unroll=L.analysis_unroll(self.n_super))
        x = L.rmsnorm(x, params["final_ln"])
        logits = L.unembed_logits(params["embed"], x)
        return logits, new_cache

    def prefill(self, params, batch):
        """Full-sequence forward that also produces the decode cache."""
        params = self._cast(params)
        cfg = self.cfg
        x, positions = self._inputs(params, batch)
        B, S = x.shape[:2]
        spec = self.cache_spec(S)
        pattern = self.pattern

        def body(h, pslice):
            new_c = {}
            for i, kind in enumerate(pattern):
                h, new_c[f"pos{i}"] = apply_block_prefill(
                    kind, pslice[f"pos{i}"], cfg, h, positions, spec)
            h = sharding.act(h, "batch", "seq", "dmodel")
            return h, new_c

        if cfg.remat == "block":
            body = jax.checkpoint(body, prevent_cse=False)
        x, cache = jax.lax.scan(body, x, params["blocks"], unroll=L.analysis_unroll(self.n_super))
        x = L.rmsnorm(x, params["final_ln"])
        logits = L.unembed_logits(params["embed"], x[:, -1:])
        return logits, cache


def apply_block_prefill(kind: str, p, cfg: ModelConfig, x, positions, spec):
    """Like apply_block but also returns the populated decode cache/state."""
    if kind in ("attn", "local_attn", "moe"):
        window = cfg.local_window if kind == "local_attn" else 0
        delta, cache = L.attention_prefill(p["attn"], cfg, x, positions, window, spec)
        x = x + delta
        if kind == "moe":
            x = x + MOE.moe_block(p["moe"], cfg, x)
        elif "mlp" in p:
            x = x + L.mlp(p["mlp"], x)
        return x, cache
    if kind == "mlstm":
        delta, st = XL.mlstm_block_prefill(p["mlstm"], cfg, x)
        return x + delta, st
    if kind == "slstm":
        delta, st = XL.slstm_block(p["slstm"], cfg, x, return_state=True)
        return x + delta, st
    if kind == "rglru":
        delta, st = RG.rglru_block(p["rglru"], cfg, x, return_state=True)
        x = x + delta
        if "mlp" in p:
            x = x + L.mlp(p["mlp"], x)
        return x, st
    raise ValueError(kind)
