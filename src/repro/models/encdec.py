"""Encoder-decoder backbone (SeamlessM4T).  The speech frontend is a stub: the
encoder consumes precomputed frame embeddings (B, S_src, D).  Decoder layers:
causal self-attention + cross-attention + SwiGLU MLP; scan over stacked layers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.parallel import sharding


def _dtype(name):
    return jnp.dtype(name)


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.encoder_layers > 0
        self.cfg = cfg

    # -- params ---------------------------------------------------------------

    def _init_enc_layer(self, key, dtype):
        k1, k2 = jax.random.split(key)
        return {"attn": L.init_attention(k1, self.cfg, dtype),
                "mlp": L.init_mlp(k2, self.cfg, dtype)}

    def _init_dec_layer(self, key, dtype):
        k1, k2, k3 = jax.random.split(key, 3)
        return {"self_attn": L.init_attention(k1, self.cfg, dtype),
                "cross_attn": L.init_attention(k2, self.cfg, dtype),
                "mlp": L.init_mlp(k3, self.cfg, dtype)}

    def init(self, key):
        cfg = self.cfg
        dtype = _dtype(cfg.param_dtype)
        ks = jax.random.split(key, 5)
        params = {
            "embed": L.init_embed(ks[0], cfg, dtype),
            "in_proj": L.dense_init(ks[1], (cfg.d_model, cfg.d_model), dtype),
            "pos_embed": L.dense_init(ks[2], (32768, cfg.d_model), dtype, scale=0.02),
            "final_ln": jnp.zeros((cfg.d_model,), dtype),
            "enc_final_ln": jnp.zeros((cfg.d_model,), dtype),
        }
        enc_keys = jax.random.split(ks[3], cfg.encoder_layers)
        dec_keys = jax.random.split(ks[4], cfg.num_layers)
        params["encoder"] = jax.vmap(lambda k: self._init_enc_layer(k, dtype))(enc_keys)
        params["decoder"] = jax.vmap(lambda k: self._init_dec_layer(k, dtype))(dec_keys)
        return params

    def param_shapes(self):
        return jax.eval_shape(lambda k: self.init(k), jax.random.key(0))

    def _cast(self, params):
        cdt = _dtype(self.cfg.compute_dtype)
        return jax.tree.map(lambda a: a.astype(cdt) if jnp.issubdtype(a.dtype, jnp.floating) else a, params)

    # -- encoder ----------------------------------------------------------------

    def _positions(self, B, S):
        return jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def encode(self, params, src_embeddings):
        cfg = self.cfg
        cdt = _dtype(cfg.compute_dtype)
        x = src_embeddings.astype(cdt) @ params["in_proj"]
        S = x.shape[1]
        x = x + params["pos_embed"][:S][None].astype(cdt)
        positions = self._positions(x.shape[0], S)

        def body(h, pslice):
            # bidirectional attention: no causal mask
            B, S, D = h.shape
            q, k, v = L._qkv(pslice["attn"], cfg, h, positions)
            mask = jnp.ones((1, 1, S, S), bool)
            att = L._sdpa(q, k, v, mask, cfg.q_per_kv) @ pslice["attn"]["wo"]
            h = h + sharding.act(att, "batch", "seq", "dmodel")
            h = h + L.mlp(pslice["mlp"], h)
            return h, None

        if cfg.remat == "block":
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(body, x, params["encoder"], unroll=L.analysis_unroll(cfg.encoder_layers))
        return L.rmsnorm(x, params["enc_final_ln"])

    # -- decoder ------------------------------------------------------------------

    def _cross(self, pslice, h, enc_kv):
        """Cross-attention with precomputed encoder K/V."""
        cfg = self.cfg
        B, S, D = h.shape
        H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        p = pslice["cross_attn"]
        hn = L.rmsnorm(h, p["ln"])
        q = (hn @ p["wq"]).reshape(B, S, H, hd)
        k, v = enc_kv
        mask = jnp.ones((1, 1, S, k.shape[1]), bool)
        out = L._sdpa(q, k, v, mask, cfg.q_per_kv) @ p["wo"]
        return sharding.act(out, "batch", "seq", "dmodel")

    def _enc_kv(self, pslice, enc_out):
        cfg = self.cfg
        B, S, D = enc_out.shape
        p = pslice["cross_attn"]
        k = (enc_out @ p["wk"]).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
        v = (enc_out @ p["wv"]).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
        return k, v

    def _decoder(self, params, tokens, enc_out):
        cfg = self.cfg
        cdt = _dtype(cfg.compute_dtype)
        x = L.embed(params["embed"], tokens).astype(cdt)
        B, S = x.shape[:2]
        x = x + params["pos_embed"][:S][None].astype(cdt)
        positions = self._positions(B, S)

        def body(h, pslice):
            B, S, D = h.shape
            q, k, v = L._qkv(pslice["self_attn"], cfg, h, positions)
            att = L.full_seq_sdpa(cfg, q, k, v, 0) @ pslice["self_attn"]["wo"]
            h = h + sharding.act(att, "batch", "seq", "dmodel")
            h = h + self._cross(pslice, h, self._enc_kv(pslice, enc_out))
            h = h + L.mlp(pslice["mlp"], h)
            return h, None

        if cfg.remat == "block":
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(body, x, params["decoder"], unroll=L.analysis_unroll(cfg.num_layers))
        return L.rmsnorm(x, params["final_ln"])

    # -- public API -----------------------------------------------------------------

    def loss(self, params, batch):
        params = self._cast(params)
        enc_out = self.encode(params, batch["src_embeddings"])
        x = self._decoder(params, batch["tokens"], enc_out)
        return L.softmax_xent(params["embed"], x, batch["labels"], self.cfg.vocab_size)

    def init_cache(self, batch: int, seq_len: int):
        cfg = self.cfg
        spec = L.CacheSpec(seq_len, cfg.kv_cache_dtype)

        def one(_):
            return {"self": L.init_kv_cache(cfg, batch, spec)}

        caches = jax.vmap(one)(jnp.arange(cfg.num_layers))
        return caches

    def prefill(self, params, batch):
        """Encode src and prefill the decoder self-attention cache."""
        params = self._cast(params)
        cfg = self.cfg
        enc_out = self.encode(params, batch["src_embeddings"])
        tokens = batch["tokens"]
        cdt = _dtype(cfg.compute_dtype)
        x = L.embed(params["embed"], tokens).astype(cdt)
        B, S = x.shape[:2]
        x = x + params["pos_embed"][:S][None].astype(cdt)
        positions = self._positions(B, S)
        spec = L.CacheSpec(S, cfg.kv_cache_dtype)

        def body(h, pslice):
            delta, cache = L.attention_prefill(pslice["self_attn"], cfg, h, positions, 0, spec)
            h = h + delta
            h = h + self._cross(pslice, h, self._enc_kv(pslice, enc_out))
            h = h + L.mlp(pslice["mlp"], h)
            return h, {"self": cache}

        if cfg.remat == "block":
            body = jax.checkpoint(body, prevent_cse=False)
        x, cache = jax.lax.scan(body, x, params["decoder"], unroll=L.analysis_unroll(cfg.num_layers))
        x = L.rmsnorm(x, params["final_ln"])
        logits = L.unembed_logits(params["embed"], x[:, -1:])
        return logits, (cache, enc_out)

    def decode_step(self, params, cache_and_enc, batch, pos):
        params = self._cast(params)
        cfg = self.cfg
        cache, enc_out = cache_and_enc
        cdt = _dtype(cfg.compute_dtype)
        x = L.embed(params["embed"], batch["tokens"]).astype(cdt)
        pidx = jnp.minimum(pos, params["pos_embed"].shape[0] - 1)
        x = x + jax.lax.dynamic_slice_in_dim(params["pos_embed"].astype(cdt),
                                             pidx, 1, axis=0)[None]

        def body(h, xs):
            pslice, cslice = xs
            delta, new_c = L.attention_decode(pslice["self_attn"], cfg, h, cslice["self"], pos, 0)
            h = h + delta
            h = h + self._cross(pslice, h, self._enc_kv(pslice, enc_out))
            h = h + L.mlp(pslice["mlp"], h)
            return h, {"self": new_c}

        x, new_cache = jax.lax.scan(body, x, (params["decoder"], cache), unroll=L.analysis_unroll(cfg.num_layers))
        x = L.rmsnorm(x, params["final_ln"])
        logits = L.unembed_logits(params["embed"], x)
        return logits, (new_cache, enc_out)
