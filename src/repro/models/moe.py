"""Mixture-of-Experts block with expert parallelism.

Top-k routing with capacity-bounded per-expert token gathering, so compiled
FLOPs stay proportional to *active* parameters (k/E of dense-all-experts), the
property the roofline analysis depends on.  Two paths:

  * gathered path (large T): per expert, select its top-C tokens by routing
    weight (argsort -- static shapes, partitioner-friendly), dense FFN on the
    (C, D) gather, scatter-add back.  C = cf * T * k / E.
  * masked-dense path (tiny T, decode): compute all experts on all tokens and
    mask -- cheaper than sorting when T is a few hundred tokens.

Experts are sharded over the "model" mesh axis via param_spec ("expert" in the
leaf path); token activations are batch-sharded.  The gather/scatter pattern
lowers to all-to-all style collectives under GSPMD.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, rmsnorm, split_keys
from repro.parallel import sharding

_CAPACITY_FACTOR = 2.0
_DENSE_PATH_MAX_TOKENS = 512


def init_moe(key, cfg: ModelConfig, dtype):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = split_keys(key, 3)
    return {
        "ln": jnp.zeros((D,), dtype),
        "router": dense_init(ks[0], (D, E), dtype),
        "expert_wi": dense_init(ks[1], (E, D, 2 * F), dtype),
        "expert_wo": dense_init(ks[2], (E, F, D), dtype, scale=F ** -0.5),
    }


def _expert_ffn(wi, wo, x):
    gu = x @ wi
    gate, up = jnp.split(gu, 2, axis=-1)
    return (jax.nn.silu(gate) * up) @ wo


def moe_block(p, cfg: ModelConfig, x):
    """x: (B, S, D) -> (B, S, D).

    Under an active mesh this runs as an explicit shard_map: tokens stay in
    their data shard, each model rank computes only its E/TP local experts on
    top-C locally-gathered tokens, and a single (T_loc, D) psum over the model
    axis combines expert contributions -- no global token gather/scatter
    (the GSPMD default for this pattern all-gathers the full token matrix;
    observed ~66s of collectives/step on moonshot train_4k)."""
    mesh = sharding.current_mesh()
    if (mesh is not None and "model" in mesh.shape
            and cfg.num_experts % mesh.shape["model"] == 0):
        return _moe_block_shardmap(p, cfg, x, mesh)
    return _moe_block_local(p, cfg, x)


def _moe_block_local(p, cfg: ModelConfig, x):
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.top_k
    T = B * S
    h = rmsnorm(x, p["ln"]).reshape(T, D)

    logits = (h @ p["router"]).astype(jnp.float32)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)                    # (T, k)
    topw = topw / (jnp.sum(topw, axis=-1, keepdims=True) + 1e-9)

    if T <= _DENSE_PATH_MAX_TOKENS:
        out = _masked_dense(p, h, topw, topi, E)
    else:
        out = _gathered(p, h, topw, topi, E, k)
    out = out.reshape(B, S, D).astype(x.dtype)
    return sharding.act(out, "batch", "seq", "dmodel")


def _moe_block_shardmap(p, cfg: ModelConfig, x, mesh):
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.top_k
    tp = mesh.shape["model"]
    E_loc = E // tp
    dp_axes = sharding.batch_axes_for(x.shape[0])

    def f(ln, router, wi, wo, xs):
        # xs: (B_loc, S, D) -- replicated over the model axis.
        Bl = xs.shape[0]
        T = Bl * S
        h = rmsnorm(xs, ln).reshape(T, D)
        logits = (h @ router).astype(jnp.float32)           # (T, E) full router
        probs = jax.nn.softmax(logits, axis=-1)
        topw, topi = jax.lax.top_k(probs, k)
        topw = topw / (jnp.sum(topw, axis=-1, keepdims=True) + 1e-9)
        # combine weight per (token, local expert)
        e0 = jax.lax.axis_index("model") * E_loc
        w_te = jnp.zeros((T, E_loc), jnp.float32)

        def add_slot(w_te, slot):
            idx = topi[:, slot] - e0
            inb = (idx >= 0) & (idx < E_loc)
            return w_te.at[jnp.arange(T), jnp.clip(idx, 0, E_loc - 1)].add(
                jnp.where(inb, topw[:, slot], 0.0))

        for slot in range(k):
            w_te = add_slot(w_te, slot)

        C = int(min(max(1, round(_CAPACITY_FACTOR * T * k / E)), T))
        gw, gi = jax.lax.top_k(w_te.T, C)                   # (E_loc, C)
        toks = jnp.take(h, gi.reshape(-1), axis=0).reshape(E_loc, C, D)
        ys = jax.vmap(_expert_ffn)(wi, wo, toks)            # (E_loc, C, D)
        ys = ys.astype(jnp.float32) * gw[..., None]
        out = jnp.zeros((T, D), jnp.float32)
        out = out.at[gi.reshape(-1)].add(ys.reshape(E_loc * C, D))
        # combine in bf16: halves the dominant psum traffic; <=TP partials of
        # already-normalized expert outputs keep the error ~1e-2 relative
        out = jax.lax.psum(out.astype(jnp.bfloat16), "model")
        return out.reshape(Bl, S, D)

    out = shard_map(
        f, mesh=mesh,
        in_specs=(P(None), P(None, None), P("model", None, None),
                  P("model", None, None), P(dp_axes, None, None)),
        out_specs=P(dp_axes, None, None),
        check_rep=False,
    )(p["ln"], p["router"], p["expert_wi"], p["expert_wo"], x)
    out = out.astype(x.dtype)
    return sharding.act(out, "batch", "seq", "dmodel")


def _masked_dense(p, h, topw, topi, E):
    T, D = h.shape
    # combine weight per (token, expert): sum over the k slots.
    w_te = jnp.zeros((T, E), jnp.float32)
    w_te = jax.vmap(lambda w, i, row: row.at[i].add(w), in_axes=(0, 0, 0))(topw, topi, w_te)
    ys = jax.vmap(lambda wi, wo: _expert_ffn(wi, wo, h), in_axes=(0, 0))(
        p["expert_wi"], p["expert_wo"]
    )                                                        # (E, T, D)
    return jnp.einsum("te,etd->td", w_te, ys.astype(jnp.float32))


def _gathered(p, h, topw, topi, E, k):
    T, D = h.shape
    C = int(max(1, round(_CAPACITY_FACTOR * T * k / E)))
    C = min(C, T)
    # Per-expert affinity: routing weight if the token picked this expert, else 0.
    w_te = jnp.zeros((T, E), jnp.float32)
    w_te = jax.vmap(lambda w, i, row: row.at[i].add(w), in_axes=(0, 0, 0))(topw, topi, w_te)

    # Top-C token ids per expert (static shapes; ties/zeros simply waste a slot).
    gather_w, gather_idx = jax.lax.top_k(w_te.T, C)          # (E, C)
    toks = jnp.take(h, gather_idx.reshape(-1), axis=0).reshape(E, C, D)

    ys = jax.vmap(lambda wi, wo, xe: _expert_ffn(wi, wo, xe), in_axes=(0, 0, 0))(
        p["expert_wi"], p["expert_wo"], toks
    )                                                        # (E, C, D)
    ys = ys.astype(jnp.float32) * gather_w[..., None]

    out = jnp.zeros((T, D), jnp.float32)
    out = out.at[gather_idx.reshape(-1)].add(ys.reshape(E * C, D))
    return out
