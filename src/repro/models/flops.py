"""Analytic FLOP accounting per (architecture x shape) cell.

`cost_analysis()` on XLA counts while-loop bodies ONCE (verified in
tests/test_dryrun.py), so scanned-layer models are undercounted by ~the layer
count.  The roofline compute term therefore uses this analytic model; the
dry-run additionally reports depth-extrapolated HLO counts as a cross-check
(see launch/dryrun.py).

Conventions: 1 MAC = 2 FLOPs; causal attention scores count the true lower
triangle (S_ctx averages S/2); training = 3x forward (fwd + 2x bwd); remat
recompute is reported separately as a multiplier, not counted as useful work.
"""

from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeConfig


def _attn_flops_per_token(cfg: ModelConfig, ctx: float) -> float:
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    proj = 2 * D * (H + 2 * KV) * hd + 2 * H * hd * D
    attn = 2 * 2 * ctx * H * hd  # scores + pv
    return proj + attn


def _mlp_flops_per_token(cfg: ModelConfig) -> float:
    return 6 * cfg.d_model * cfg.d_ff if cfg.d_ff else 0.0


def _moe_flops_per_token(cfg: ModelConfig) -> float:
    router = 2 * cfg.d_model * cfg.num_experts
    return router + cfg.top_k * 6 * cfg.d_model * cfg.d_ff


def _mlstm_flops_per_token(cfg: ModelConfig, decode: bool) -> float:
    D = cfg.d_model
    Din = 2 * D
    dh = Din // cfg.num_heads
    proj = 2 * D * Din * 2 + 2 * Din * D + 3 * 2 * Din * dh + 2 * 4 * Din
    Lc = 1 if decode else cfg.mlstm_chunk
    cell = 4 * Lc * Din + 6 * dh * Din  # intra-chunk + state/inter
    return proj + cell


def _slstm_flops_per_token(cfg: ModelConfig) -> float:
    D = cfg.d_model
    dh = D // cfg.num_heads
    F = ((4 * D // 3 + 63) // 64) * 64
    return 4 * 2 * D * D + 4 * 2 * D * dh + 2 * D * D + 6 * D * F


def _rglru_flops_per_token(cfg: ModelConfig) -> float:
    D = cfg.d_model
    return 5 * 2 * D * D + 2 * cfg.rglru_conv_width * D + 12 * D


def _block_flops_per_token(cfg: ModelConfig, kind: str, ctx: float,
                           decode: bool) -> float:
    if kind == "attn":
        return _attn_flops_per_token(cfg, ctx) + _mlp_flops_per_token(cfg)
    if kind == "local_attn":
        local_ctx = min(ctx, float(cfg.local_window or ctx))
        return _attn_flops_per_token(cfg, local_ctx) + _mlp_flops_per_token(cfg)
    if kind == "moe":
        return _attn_flops_per_token(cfg, ctx) + _moe_flops_per_token(cfg)
    if kind == "mlstm":
        return _mlstm_flops_per_token(cfg, decode)
    if kind == "slstm":
        return _slstm_flops_per_token(cfg)
    if kind == "rglru":
        return _rglru_flops_per_token(cfg) + _mlp_flops_per_token(cfg)
    raise ValueError(kind)


def forward_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Global forward FLOPs for one step of this cell."""
    B, S = shape.global_batch, shape.seq_len
    decode = shape.kind == "decode"
    if decode:
        tokens = float(B)          # one new token per sequence
        ctx = float(S)             # attends over the full cache
    else:
        tokens = float(B) * S
        ctx = S / 2.0              # causal average context

    per_tok = sum(_block_flops_per_token(cfg, k, ctx, decode)
                  for k in cfg.block_pattern) / len(cfg.block_pattern)
    total = tokens * per_tok * cfg.num_layers
    # unembed (tied): logits for every processed token in train; last/one token
    # in prefill/decode
    V = cfg.padded_vocab()
    if shape.kind == "train":
        total += tokens * 2 * cfg.d_model * V
    else:
        total += float(B) * 2 * cfg.d_model * V
    if cfg.family == "encdec":
        S_src = max(S // 8, 16)
        enc_tokens = float(B) * S_src
        enc_per_tok = _attn_flops_per_token(cfg, S_src / 2.0) + _mlp_flops_per_token(cfg)
        total += enc_tokens * enc_per_tok * cfg.encoder_layers
        # decoder cross-attention
        cross = 2 * cfg.d_model * (cfg.num_heads + 2 * cfg.num_kv_heads) * cfg.head_dim \
            + 2 * 2 * S_src * cfg.num_heads * cfg.head_dim
        total += tokens * cross * cfg.num_layers
    return total


def param_count(cfg: ModelConfig) -> float:
    """Total parameters from the config (cheap, no tracing)."""
    D, V = cfg.d_model, cfg.padded_vocab()
    per_layer = 0.0
    for kind in cfg.block_pattern:
        H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        attn = D * (H + 2 * KV) * hd + H * hd * D
        mlp = 3 * D * cfg.d_ff
        if kind in ("attn", "local_attn"):
            per_layer += attn + mlp
        elif kind == "moe":
            per_layer += attn + D * cfg.num_experts + cfg.num_experts * 3 * D * cfg.d_ff
        elif kind == "mlstm":
            Din = 2 * D
            per_layer += 2 * D * Din + Din * D + 3 * Din * (Din // H) + 2 * Din * H
        elif kind == "slstm":
            F = ((4 * D // 3 + 63) // 64) * 64
            per_layer += 4 * (D * D + D * (D // H)) + 3 * D * F + D * D
        elif kind == "rglru":
            per_layer += 5 * D * D + mlp
    total = V * D + per_layer * cfg.num_layers / len(cfg.block_pattern)
    if cfg.family == "encdec":
        total += (4 * D * D + 3 * D * cfg.d_ff) * cfg.encoder_layers
        total += 4 * D * D * cfg.num_layers  # cross-attention
        total += 32768 * D                   # positional table
    return total


def _bytes_of(dtype: str) -> int:
    return {"float32": 4, "bfloat16": 2, "float16": 2, "int8": 1}[dtype]


def cell_bytes(cfg: ModelConfig, shape: ShapeConfig, n_dev: int,
               model_par: int) -> dict:
    """Analytic HBM traffic per device per step, assuming block-level fusion
    (flash blocks stay in VMEM; weights read once per use).  XLA's
    'bytes accessed' has no fusion model and overestimates ~30x, so the
    roofline memory term uses this estimate and reports the HLO number as an
    upper bound."""
    P = param_count(cfg)
    pb = _bytes_of(cfg.param_dtype)
    ob = _bytes_of(cfg.optimizer_dtype)
    ab = _bytes_of(cfg.compute_dtype)
    dp = max(n_dev // model_par, 1)
    B, S = shape.global_batch, shape.seq_len
    P_dev = P / n_dev  # params sharded over the whole mesh (TP x FSDP)

    if shape.kind == "train":
        # fwd read + bwd read + grad write (param dtype) + AdamW read/write of
        # p, mu, nu (optimizer dtype)
        param_traffic = P_dev * (3 * pb + 2 * (pb + 2 * ob))
        tokens_dev = B * S / dp  # model ranks replicate tokens
        act_traffic = tokens_dev * cfg.d_model * ab * 10 * cfg.num_layers / model_par \
            + tokens_dev * cfg.d_model * ab * 4 * cfg.num_layers  # unsharded boundary IO
        logits_traffic = tokens_dev * (cfg.padded_vocab() / model_par) * ab * 2
        total = param_traffic + act_traffic + logits_traffic
    elif shape.kind == "prefill":
        param_traffic = P_dev * pb
        tokens_dev = B * S / dp
        act_traffic = tokens_dev * cfg.d_model * ab * 6 * cfg.num_layers / model_par
        cache_traffic = (tokens_dev * cfg.num_kv_heads * cfg.head_dim * 2
                         * _bytes_of(cfg.kv_cache_dtype) * cfg.num_layers)
        total = param_traffic + act_traffic + cache_traffic
    else:  # decode: params + full cache read once
        param_traffic = P_dev * pb
        cache_bytes = (B * S * cfg.num_kv_heads * cfg.head_dim * 2
                       * _bytes_of(cfg.kv_cache_dtype) * cfg.num_layers)
        if cfg.sub_quadratic:
            # recurrent state instead of a KV cache
            cache_bytes = (B * (2 * cfg.d_model) ** 2 / cfg.num_heads * 4
                           * cfg.num_layers)
        total = param_traffic + cache_bytes / n_dev
    return {"bytes_per_dev": total}


def cell_flops(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    fwd = forward_flops(cfg, shape)
    if shape.kind == "train":
        useful = 3.0 * fwd
        hw_factor = 4.0 / 3.0 if cfg.remat == "block" else 1.0
    else:
        useful = fwd
        hw_factor = 1.0
    return {"forward": fwd, "useful": useful,
            "expected_hw": useful * hw_factor}
