"""Transformer primitives: norms, RoPE / M-RoPE, GQA attention (train + cached
decode, causal or local-window), SwiGLU MLP, embeddings, quantized KV cache."""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map as _shard_map

from repro.configs.base import ModelConfig
from repro.parallel import sharding

# ---------------------------------------------------------------- init helpers

def dense_init(key, shape, dtype, scale=None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------- norms

def rmsnorm(x, scale, eps=1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def head_rmsnorm(x, scale, eps=1e-6):
    """qk-norm: rmsnorm over the head_dim axis."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dtype)


# ----------------------------------------------------------------------- rope

def rope_freqs(head_dim: int, theta: float = 10000.0):
    return theta ** (-jnp.arange(0, head_dim // 2, dtype=jnp.float32) / (head_dim // 2))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]                       # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float = 10000.0):
    """M-RoPE (Qwen2-VL): rotary pairs split into 3 sections (t/h/w), each
    rotated by its own position stream.  positions3: (3, ..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    sect = [half - 2 * (half // 3), half // 3, half // 3]  # t gets the remainder
    freqs = rope_freqs(hd, theta)
    pieces = []
    start = 0
    for comp in range(3):
        f = freqs[start : start + sect[comp]]
        ang = positions3[comp][..., None].astype(jnp.float32) * f
        pieces.append(ang)
        start += sect[comp]
    angles = jnp.concatenate(pieces, axis=-1)[..., None, :]  # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------- attention

def init_attention(key, cfg: ModelConfig, dtype):
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = split_keys(key, 4)
    p = {
        "ln": jnp.zeros((D,), dtype),
        "wq": dense_init(ks[0], (D, H * hd), dtype),
        "wk": dense_init(ks[1], (D, KV * hd), dtype),
        "wv": dense_init(ks[2], (D, KV * hd), dtype),
        "wo": dense_init(ks[3], (H * hd, D), dtype, scale=(H * hd) ** -0.5),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((cfg.head_dim,), dtype)
        p["k_norm"] = jnp.zeros((cfg.head_dim,), dtype)
    return p


def _qkv(p, cfg: ModelConfig, x, positions):
    B, S, D = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    h = rmsnorm(x, p["ln"])
    q = (h @ p["wq"]).reshape(B, S, H, hd)
    k = (h @ p["wk"]).reshape(B, S, KV, hd)
    v = (h @ p["wv"]).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = head_rmsnorm(q, p["q_norm"])
        k = head_rmsnorm(k, p["k_norm"])
    if cfg.mrope:
        q = apply_mrope(q, positions)
        k = apply_mrope(k, positions)
    elif cfg.rope:
        q = apply_rope(q, positions)
        k = apply_rope(k, positions)
    q = sharding.act(q, "batch", "seq", "heads", None)
    k = sharding.act(k, "batch", "seq", None, None)
    return q, k, v


def _sdpa(q, k, v, mask, q_per_kv: int):
    """q: (B,Sq,H,hd), k/v: (B,Sk,KV,hd), mask: (B,1,Sq,Sk) or broadcastable."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    q = q.reshape(B, Sq, KV, q_per_kv, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k).astype(jnp.float32)
    scores = scores * (hd ** -0.5)
    scores = jnp.where(mask[:, :, None] if mask.ndim == 4 else mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
    return out.reshape(B, Sq, H * hd)


# When True (set by the dry-run's cost-extrapolation variants), chunk loops are
# unrolled so XLA's cost analysis -- which counts while-loop bodies once -- sees
# every iteration.  Never enabled for real execution.
ANALYSIS_UNROLL = False


# Cap on unrolled copies: bounds depth-variant compile time on 1 CPU core.
# Inner loops longer than the cap stay partially rolled; their residual
# undercount is covered by the analytic FLOPs model (models/flops.py).
ANALYSIS_UNROLL_CAP = 4


def analysis_unroll(n: int) -> int:
    """lax.scan unroll factor: (capped) full length in analysis mode so loop
    iterations appear in the HLO (cost analysis counts loop bodies once)."""
    import repro.models.layers as _self
    return min(max(int(n), 1), ANALYSIS_UNROLL_CAP) if _self.ANALYSIS_UNROLL else 1


def _chunk_map(fn, xs, n):
    """lax.map with a partially-unrolled variant for analysis mode."""
    if ANALYSIS_UNROLL and n <= ANALYSIS_UNROLL_CAP:
        outs = [fn(jax.tree.map(lambda a: a[i], xs)) for i in range(n)]
        return jnp.stack(outs)
    return jax.lax.map(fn, xs)


def flash_sdpa(q, k, v, q_per_kv: int, window: int = 0,
               bq: int = 1024, bk: int = 1024):
    """Flash-style causal attention in pure JAX: online softmax over K/V chunks,
    scan over Q chunks.  Peak memory O(bq*bk) per (batch, head) instead of
    O(S^2).  For local windows, each Q chunk gathers only its K window.

    q: (B,Sq,H,hd), k/v: (B,Sk,KV,hd) -> (B,Sq,H*hd)
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    KV = k.shape[2]
    g = q_per_kv
    bq = min(bq, Sq)
    while Sq % bq:
        bq //= 2
    nq = Sq // bq
    scale = hd ** -0.5

    qc = q.reshape(B, nq, bq, KV, g, hd).swapaxes(0, 1)   # (nq,B,bq,KV,g,hd)

    if window > 0:
        span = window + bq                                 # static K slice per Q chunk
        span = min(span, Sk)

        def one_chunk(i, qb):
            start = jnp.clip(i * bq + bq - span, 0, Sk - span)
            kb = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
            qpos = i * bq + jnp.arange(bq)
            kpos = start + jnp.arange(span)
            m = (kpos[None] <= qpos[:, None]) & (kpos[None] > qpos[:, None] - window)
            s = jnp.einsum("bqkgh,bskh->bkgqs", qb, kb).astype(jnp.float32) * scale
            s = jnp.where(m[None, None, None], s, -1e30)
            w = jax.nn.softmax(s, axis=-1).astype(vb.dtype)
            return jnp.einsum("bkgqs,bskh->bqkgh", w, vb)

        one_chunk = jax.checkpoint(one_chunk)
        outs = _chunk_map(lambda args: one_chunk(*args), (jnp.arange(nq), qc), nq)
        return outs.swapaxes(0, 1).reshape(B, Sq, H * hd).astype(q.dtype)

    bk = min(bk, Sk)
    while Sk % bk:
        bk //= 2
    nk = Sk // bk
    kc = k.reshape(B, nk, bk, KV, hd).swapaxes(0, 1)
    vc = v.reshape(B, nk, bk, KV, hd).swapaxes(0, 1)

    def q_chunk(i, qb):
        # online softmax across K chunks
        m0 = jnp.full((B, KV, g, bq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, KV, g, bq), jnp.float32)
        acc0 = jnp.zeros((B, bq, KV, g, hd), jnp.float32)
        qpos = i * bq + jnp.arange(bq)

        def kv_step(carry, xs):
            m_prev, l_prev, acc = carry
            j, kb, vb = xs
            kpos = j * bk + jnp.arange(bk)
            valid = kpos[None] <= qpos[:, None]               # (bq,bk) causal
            s = jnp.einsum("bqkgh,bskh->bkgqs", qb, kb).astype(jnp.float32) * scale
            s = jnp.where(valid[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(valid[None, None, None], p, 0.0)
            corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
            l_new = l_prev * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(vb.dtype), vb).astype(jnp.float32)
            acc = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
            return (m_new, l_new, acc), None

        kv_step_ck = jax.checkpoint(kv_step)  # recompute p in backward (flash)
        if ANALYSIS_UNROLL:
            carry = (m0, l0, acc0)
            for j in range(nk):
                carry, _ = kv_step_ck(carry, (jnp.asarray(j), kc[j], vc[j]))
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(
                kv_step_ck, (m0, l0, acc0), (jnp.arange(nk), kc, vc))
        out = acc / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
        return out

    q_chunk = jax.checkpoint(q_chunk)
    outs = _chunk_map(lambda args: q_chunk(*args), (jnp.arange(nq), qc), nq)
    out = outs.swapaxes(0, 1).reshape(B, Sq, H, hd)
    return out.reshape(B, Sq, H * hd).astype(q.dtype)


def causal_mask(S: int, window: int = 0, dtype=jnp.bool_):
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    m = j <= i
    if window > 0:
        m = m & (j > i - window)
    return m[None, None]  # (1,1,S,S)


def full_seq_sdpa(cfg: ModelConfig, q, k, v, window: int, causal: bool = True):
    if cfg.attn_impl == "flash" and causal:
        return flash_sdpa(q, k, v, cfg.q_per_kv, window,
                          cfg.flash_block_q, cfg.flash_block_k)
    S, Sk = q.shape[1], k.shape[1]
    mask = causal_mask(S, window) if causal else jnp.ones((1, 1, S, Sk), bool)
    return _sdpa(q, k, v, mask, cfg.q_per_kv)


def attention(p, cfg: ModelConfig, x, positions, window: int = 0):
    """Full-sequence attention (train / prefill)."""
    B, S, D = x.shape
    q, k, v = _qkv(p, cfg, x, positions)
    out = full_seq_sdpa(cfg, q, k, v, window)
    out = out @ p["wo"]
    return sharding.act(out, "batch", "seq", "dmodel")


# --------------------------------------------------------- KV cache (+ int8)

@dataclasses.dataclass(frozen=True)
class CacheSpec:
    seq_len: int
    dtype: str  # "bfloat16" | "float32" | "int8"


def init_kv_cache(cfg: ModelConfig, batch: int, spec: CacheSpec):
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    S = spec.seq_len
    if spec.dtype == "int8":
        z8 = jnp.zeros((batch, S, KV, hd), jnp.int8)
        zs = jnp.zeros((batch, S, KV, 1), jnp.float32)
        return {"k": z8, "v": z8, "k_scale": zs, "v_scale": zs}
    z = jnp.zeros((batch, S, KV, hd), jnp.dtype(spec.dtype))
    return {"k": z, "v": z}


def _quant(x):
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0 + 1e-8
    return jnp.round(x / scale).astype(jnp.int8), scale.astype(jnp.float32)


def _dequant(x8, scale, dtype):
    return (x8.astype(jnp.float32) * scale).astype(dtype)


def update_kv_cache(cache, k_new, v_new, pos):
    """k_new/v_new: (B,1,KV,hd); pos: scalar int32 write index."""
    quantized = "k_scale" in cache
    if quantized:
        k8, ks = _quant(k_new)
        v8, vs = _quant(v_new)
        cache = dict(cache)
        cache["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], k8, pos, axis=1)
        cache["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], v8, pos, axis=1)
        cache["k_scale"] = jax.lax.dynamic_update_slice_in_dim(cache["k_scale"], ks, pos, axis=1)
        cache["v_scale"] = jax.lax.dynamic_update_slice_in_dim(cache["v_scale"], vs, pos, axis=1)
        return cache
    cache = dict(cache)
    cache["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), pos, axis=1)
    cache["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), pos, axis=1)
    return cache


def read_kv_cache(cache, dtype):
    if "k_scale" in cache:
        return (_dequant(cache["k"], cache["k_scale"], dtype),
                _dequant(cache["v"], cache["v_scale"], dtype))
    return cache["k"].astype(dtype), cache["v"].astype(dtype)


def attention_decode(p, cfg: ModelConfig, x, cache, pos, window: int = 0):
    """One-token decode: x (B,1,D); attends to cache[0..pos] inclusive."""
    B = x.shape[0]
    if cfg.mrope:
        positions = jnp.broadcast_to(pos, (3, B, 1))
    else:
        positions = jnp.broadcast_to(pos, (B, 1))
    q, k_new, v_new = _qkv(p, cfg, x, positions)
    cache = update_kv_cache(cache, k_new, v_new, pos)
    k, v = read_kv_cache(cache, x.dtype)
    S = k.shape[1]
    j = jnp.arange(S)[None, None, None, :]                # (1,1,1,S)
    mask = j <= pos
    if window > 0:
        mask = mask & (j > pos - window)
    out = _sdpa(q, k, v, mask, cfg.q_per_kv) @ p["wo"]
    return sharding.act(out, "batch", None, "dmodel"), cache


def attention_decode_windowed(p, cfg: ModelConfig, x, cache, pos):
    """Rolling-window decode for local attention: cache holds the last W
    positions; slot = pos % W; absolute positions tracked in cache["pos_ids"]."""
    B = x.shape[0]
    W = cache["k"].shape[1]
    positions = jnp.broadcast_to(pos, (B, 1))
    q, k_new, v_new = _qkv(p, cfg, x, positions)
    slot = jnp.remainder(pos, W)
    pos_ids = jax.lax.dynamic_update_slice_in_dim(
        cache["pos_ids"], pos[None].astype(jnp.int32), slot, axis=0)
    cache = dict(cache)
    cache["pos_ids"] = pos_ids
    cache = update_kv_cache(cache, k_new, v_new, slot)
    k, v = read_kv_cache(cache, x.dtype)
    valid = (pos_ids >= 0) & (pos_ids <= pos) & (pos_ids > pos - W)
    mask = valid[None, None, None, :]
    out = _sdpa(q, k, v, mask, cfg.q_per_kv) @ p["wo"]
    return sharding.act(out, "batch", None, "dmodel"), cache


def _fill_cache(cfg: ModelConfig, k, v, spec: CacheSpec):
    """Quantize/cast full-sequence K,V (B,S,KV,hd) into a decode cache."""
    if spec.dtype == "int8":
        k8, ks = _quant(k)
        v8, vs = _quant(v)
        return {"k": k8, "v": v8, "k_scale": ks, "v_scale": vs}
    dt = jnp.dtype(spec.dtype)
    return {"k": k.astype(dt), "v": v.astype(dt)}


def attention_prefill(p, cfg: ModelConfig, x, positions, window: int, spec: CacheSpec):
    """Full-sequence attention that also emits the populated decode cache."""
    B, S, D = x.shape
    q, k, v = _qkv(p, cfg, x, positions)
    out = full_seq_sdpa(cfg, q, k, v, window) @ p["wo"]
    out = sharding.act(out, "batch", "seq", "dmodel")
    if window > 0:
        W = min(window, S)
        abs_pos = jnp.arange(S - W, S, dtype=jnp.int32)
        slots = jnp.remainder(abs_pos, W)          # slot = abs_pos % W
        # place the window into its rolling slots
        rolled = {}
        for kk, vv in _fill_cache(cfg, k[:, S - W:], v[:, S - W:], spec).items():
            rolled[kk] = jnp.zeros_like(vv).at[:, slots].set(vv)
        rolled["pos_ids"] = jnp.zeros((W,), jnp.int32).at[slots].set(abs_pos)
        cache = rolled
    else:
        cache = _fill_cache(cfg, k, v, spec)
    return out, cache


# ----------------------------------------------------------------------- MLP

def init_mlp(key, cfg: ModelConfig, dtype, d_ff: int | None = None):
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    ks = split_keys(key, 2)
    return {
        "ln": jnp.zeros((D,), dtype),
        "wi_mlp_up": dense_init(ks[0], (D, 2 * F), dtype),
        "wo_mlp": dense_init(ks[1], (F, D), dtype, scale=F ** -0.5),
    }


def mlp(p, x):
    h = rmsnorm(x, p["ln"])
    gu = h @ p["wi_mlp_up"]
    gate, up = jnp.split(gu, 2, axis=-1)
    gate = sharding.act(gate, "batch", "seq", "ff")
    h = jax.nn.silu(gate) * up
    out = h @ p["wo_mlp"]
    return sharding.act(out, "batch", "seq", "dmodel")


# ----------------------------------------------------------------- embeddings

def init_embed(key, cfg: ModelConfig, dtype):
    V = cfg.padded_vocab()
    return {"embedding": dense_init(key, (V, cfg.d_model), dtype, scale=0.02)}


def embed(p, tokens):
    """Token embedding lookup against the vocab-sharded table.

    Explicit shard_map: each vocab shard gathers locally and a (B,S,D) psum
    combines -- the partitioner's default strategy materializes a full-vocab
    one-hot (observed 12 GiB/device), which this avoids."""
    from jax.sharding import PartitionSpec as P

    table = p["embedding"]
    V = table.shape[0]
    mesh = sharding.current_mesh()
    if mesh is None or "model" not in mesh.shape or V % mesh.shape["model"]:
        out = jnp.take(table, tokens, axis=0)
        return sharding.act(out, "batch", "seq", "dmodel")

    dp = sharding.batch_axes_for(tokens.shape[0])
    Vloc = V // mesh.shape["model"]

    def f(tab, toks):
        off = jax.lax.axis_index("model") * Vloc
        idx = toks - off
        inb = (idx >= 0) & (idx < Vloc)
        rows = jnp.take(tab, jnp.clip(idx, 0, Vloc - 1), axis=0)
        rows = jnp.where(inb[..., None], rows, 0)
        return jax.lax.psum(rows, "model")

    out = _shard_map(
        f, mesh=mesh,
        in_specs=(P("model", None), P(dp, None)),
        out_specs=P(dp, None, None),
        check_rep=False,
    )(table, tokens)
    return sharding.act(out, "batch", "seq", "dmodel")


def unembed_logits(p, x):
    """Logits (B,S,V), vocab-sharded."""
    logits = x @ p["embedding"].T
    return sharding.act(logits, "batch", "seq", "vocab")


def _xent_from_logits(lg, labels, offset, valid_cols):
    """Per-shard xent pieces. lg: (B,S,Vloc) fp32 (already masked); labels
    global ids; offset = first global column of this shard."""
    Vloc = lg.shape[-1]
    m_local = jnp.max(lg, axis=-1)
    idx = labels - offset
    inb = (idx >= 0) & (idx < valid_cols)
    ll = jnp.take_along_axis(lg, jnp.clip(idx, 0, Vloc - 1)[..., None], axis=-1)[..., 0]
    return m_local, ll, inb


def softmax_xent(p_embed, x, labels, vocab_size: int):
    """Cross-entropy over a (possibly model-axis-sharded) vocab, computed with
    an explicit shard_map: local reductions + tiny (B,S) pmax/psum.  This keeps
    the partitioner from all-gathering full logits (~12 GiB/device observed)
    or resharding the embedding table for a label gather."""
    from jax.sharding import PartitionSpec as P

    logits = unembed_logits(p_embed, x)
    V = logits.shape[-1]
    mesh = sharding.current_mesh()

    if mesh is None or "model" not in mesh.shape or V % mesh.shape["model"]:
        lg = logits.astype(jnp.float32)
        if V > vocab_size:
            lg = jnp.where(jnp.arange(V) < vocab_size, lg, -1e30)
        lse = jax.scipy.special.logsumexp(lg, axis=-1)
        ll = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
        return jnp.mean(lse - ll)

    dp = sharding.batch_axes_for(logits.shape[0])
    Vloc = V // mesh.shape["model"]

    @jax.custom_jvp
    def pmax_const(v):
        return jax.lax.pmax(v, "model")

    @pmax_const.defjvp
    def _pmax_jvp(primals, tangents):
        # the max is a constant log-shift (cancels analytically) -> zero tangent
        (v,), (dv,) = primals, tangents
        return pmax_const(v), jnp.zeros_like(dv)

    def f(lg, lab):
        shard = jax.lax.axis_index("model")
        offset = shard * Vloc
        lg = lg.astype(jnp.float32)
        if V > vocab_size:
            cols = offset + jnp.arange(Vloc)
            lg = jnp.where(cols < vocab_size, lg, -1e30)
        valid = jnp.minimum(jnp.maximum(vocab_size - offset, 0), Vloc)
        m_local, ll, inb = _xent_from_logits(lg, lab, offset, valid)
        m = pmax_const(m_local)
        z = jax.lax.psum(jnp.sum(jnp.exp(lg - m[..., None]), axis=-1), "model")
        lse = jnp.log(z) + m
        label_logit = jax.lax.psum(jnp.where(inb, ll, 0.0), "model")
        return lse - label_logit

    per_tok = _shard_map(
        f, mesh=mesh,
        in_specs=(P(dp, None, "model"), P(dp, None)),
        out_specs=P(dp, None),
        check_rep=False,
    )(logits, labels)
    return jnp.mean(per_tok)
