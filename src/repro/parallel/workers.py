"""Worker-process side of the learner/worker executor (`repro.parallel`).

One `worker_main` loop runs in each spawn-started process of a
`ProcessExecutor` pool: pull a task from the shared queue, execute it, push
`(job_id, chunk_idx, status, payload)` back.  Tasks are whole stacked
k*L-run inner searches (`FanoutSearchSpec`, see `repro.core.bo`) -- exactly
the items a `SearchSession.pending()` emits, with their content-derived
seeds -- so the learner process keeps every outer GP/acquisition/session
state machine and workers only ever run embarrassingly-parallel inner work.

Module contract: **stdlib-only at import time**.  Workers must start with a
clean interpreter -- in particular they must not inherit the parent's jax
runtime or its x64 global state, which a fork-started child would copy
wholesale.  `ProcessExecutor` always uses the spawn start method, and this
module enforces the invariant at two points:

  * `worker_main` refuses to run searches in a fork-started child -- one
    where this module was imported by a *different* process (the PID
    sentinel below).  A spawn child re-imports everything fresh, so jax in
    `sys.modules` at boot merely means the parent's `__main__` module
    imports it (e.g. `examples/codesign_service.py`) -- that is clean,
    newly initialized state, not inheritance;
  * after any search whose resolved evaluation backend is "numpy", the
    worker verifies that no jax *evaluation-engine* module was pulled in
    (`repro.timeloop.batch_jax`, the Pallas kernels) and that the global
    `jax_enable_x64` flag is still off.  (The GP/BO surrogate layer itself
    is jax-based on every backend and scopes x64 per call -- see
    `repro.core.gp` -- so "never imports jax at all" is enforced only up to
    the moment a search runs; the regression test probes a fresh worker
    before its first search to pin that.)

The "probe" task kind returns a snapshot of the worker's module/x64 state
for that regression test (`tests/test_executor.py`).
"""

from __future__ import annotations

import os
import sys
import traceback

# jax modules that a numpy-backend search must never pull in: the batched
# device evaluation engine and the Pallas inner kernels.
_JAX_ENGINE_MODULES = ("repro.timeloop.batch_jax", "repro.kernels.edp_reduce")

# Fork-detection sentinel: a spawn-started worker re-imports this module in
# its own process (PID matches at `worker_main` time); a fork-started child
# inherits the parent's import (PID mismatch) -- and with it the parent's
# live jax runtime and x64 globals.
_IMPORT_PID = os.getpid()


def _jax_modules() -> list[str]:
    return sorted(m for m in sys.modules if m.split(".")[0] == "jax")


def _x64_enabled() -> bool:
    jax = sys.modules.get("jax")
    return bool(jax is not None and jax.config.jax_enable_x64)


def _probe_report(inherited_jax: list[str]) -> dict:
    """Snapshot of the invariants the no-jax regression test pins."""
    return {
        "inherited_jax": list(inherited_jax),
        "jax_modules": _jax_modules(),
        "engine_modules": [m for m in _JAX_ENGINE_MODULES if m in sys.modules],
        "x64_enabled": _x64_enabled(),
        "start_method": type(sys.modules.get("__mp_main__")).__name__
        if "__mp_main__" in sys.modules else None,
    }


def _run_search(spec, inherited_jax: list[str]) -> list:
    if inherited_jax:
        raise RuntimeError(
            f"fork-started worker inherited jax state from its parent "
            f"(modules {inherited_jax[:3]}...); ProcessExecutor workers must "
            "be spawn-started so the parent's jax runtime and x64 globals "
            "cannot leak in")
    entries = spec.run()
    if spec.engine is None or spec.engine.resolve_backend() == "numpy":
        loaded = [m for m in _JAX_ENGINE_MODULES if m in sys.modules]
        if loaded:
            raise RuntimeError(
                f"numpy-backend search imported jax evaluation modules in a "
                f"worker: {loaded}")
        if _x64_enabled():
            raise RuntimeError(
                "a worker search flipped the process-global jax_enable_x64 "
                "flag; x64 must stay scoped (repro.core.gp.enable_x64)")
    return entries


def worker_main(task_q, result_q) -> None:
    """Persistent worker loop: runs until a `None` sentinel arrives.

    Tasks are `(kind, job_id, chunk_idx, payload)` tuples:
      ("search", jid, idx, FanoutSearchSpec) -> list of (mapping, EDP) entries
      ("probe",  jid, idx, None)             -> module/x64 state snapshot
    Results are `(job_id, chunk_idx, "ok", payload)` or
    `(job_id, chunk_idx, "error", (repr, traceback_text))` -- the learner
    re-raises errors with the worker traceback attached.
    """
    # jax modules count as *inherited* only under fork (module imported by a
    # different process); a spawn child whose __main__ imports jax booted
    # with fresh, unleaked state.
    forked = os.getpid() != _IMPORT_PID
    inherited_jax = _jax_modules() if forked else []
    while True:
        task = task_q.get()
        if task is None:
            return
        kind, jid, idx, payload = task
        try:
            if kind == "probe":
                out = _probe_report(inherited_jax)
            elif kind == "search":
                out = _run_search(payload, inherited_jax)
            else:
                raise ValueError(f"unknown worker task kind {kind!r}")
            result_q.put((jid, idx, "ok", out))
        except BaseException as e:  # noqa: BLE001 -- report, keep serving
            result_q.put((jid, idx, "error",
                          (repr(e), traceback.format_exc())))
