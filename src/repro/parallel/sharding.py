"""Logical-axis sharding rules (DP / FSDP / TP / EP / SP).

Models annotate activations with *logical* axis names; the active `AxisRules`
maps logical names to mesh axes.  Parameters get PartitionSpecs from their tree
path + shape via `param_spec`.  Everything is a no-op when no mesh is active,
so the same model code runs in single-device smoke tests and in the 512-chip
dry-run.

Baseline strategy (see DESIGN.md):
  batch    -> ("pod", "data")     pure DP across pods, DP within pod
  d_ff / heads / vocab / experts -> "model"   (TP / EP)
  fsdp     -> "data"              parameters additionally sharded over data
  seq      -> optionally "model"  (sequence parallelism for long contexts)
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AxisRules:
    batch: tuple | str | None = ("pod", "data")
    seq: str | None = None            # "model" => sequence parallelism
    dmodel: str | None = None
    heads: str | None = "model"
    ff: str | None = "model"
    vocab: str | None = "model"
    expert: str | None = "model"
    fsdp: str | None = "data"         # param dim sharded over data axis
    kv_len: str | None = None         # decode: KV-cache length axis

    def resolve(self, name: str | None):
        if name is None:
            return None
        return getattr(self, name)


_STATE = threading.local()


def _get():
    if not hasattr(_STATE, "mesh"):
        _STATE.mesh, _STATE.rules = None, AxisRules()
    return _STATE


@contextlib.contextmanager
def use_mesh(mesh: Mesh | None, rules: AxisRules | None = None):
    st = _get()
    prev = (st.mesh, st.rules)
    st.mesh = mesh
    st.rules = rules or AxisRules()
    try:
        yield
    finally:
        st.mesh, st.rules = prev


def current_mesh() -> Mesh | None:
    return _get().mesh


def current_rules() -> AxisRules:
    return _get().rules


def _filter_spec(mesh: Mesh, spec_axes: tuple) -> P:
    """Drop axes not present in the mesh (e.g. 'pod' on the single-pod mesh),
    and de-duplicate mesh axes across dims with rightmost-dim priority (under
    sequence parallelism both 'seq' and 'ff'/'heads' may map to 'model'; the
    inner/TP dim wins)."""
    names = set(mesh.axis_names)

    def ok(a):
        if a is None:
            return None
        if isinstance(a, (tuple, list)):
            kept = tuple(x for x in a if x in names)
            return kept if kept else None
        return a if a in names else None

    axes = [ok(a) for a in spec_axes]
    used: set = set()
    for i in range(len(axes) - 1, -1, -1):  # rightmost wins
        a = axes[i]
        if a is None:
            continue
        flat = tuple(a) if isinstance(a, tuple) else (a,)
        if any(x in used for x in flat):
            kept = tuple(x for x in flat if x not in used)
            axes[i] = kept if kept else None
            flat = kept
        used.update(flat)
    return P(*axes)


def act(x, *logical_axes):
    """Constrain an activation's sharding by logical axis names (None = any)."""
    st = _get()
    if st.mesh is None:
        return x
    axes = tuple(st.rules.resolve(a) for a in logical_axes)
    spec = _filter_spec(st.mesh, axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(st.mesh, spec))


def batch_axes_for(size: int):
    """Mesh axes for a batch dim of `size` under the current rules, or None
    when the size doesn't divide the axes (e.g. global_batch=1 long-context)."""
    st = _get()
    if st.mesh is None:
        return None
    dp = _filter_spec(st.mesh, (st.rules.batch,))[0]
    if dp is None:
        return None
    axes = dp if isinstance(dp, (tuple, list)) else (dp,)
    total = 1
    for a in axes:
        total *= st.mesh.shape[a]
    return dp if total and size % total == 0 else None


# --- parameter specs -------------------------------------------------------------

def _divides(mesh: Mesh, axis, size: int) -> bool:
    if axis is None:
        return False
    if isinstance(axis, (tuple, list)):
        total = 1
        for a in axis:
            if a in mesh.shape:
                total *= mesh.shape[a]
        return total > 0 and size % total == 0
    return axis in mesh.shape and size % mesh.shape[axis] == 0


def param_spec(path: str, shape: tuple, mesh: Mesh, rules: AxisRules,
               stacked: bool = True) -> P:
    """PartitionSpec for one parameter leaf.

    `path` is the '/'-joined tree path; `stacked` params carry a leading
    layer-stack dim (never sharded).  Policy: the tensor-parallel dim follows
    the leaf's role (ff/heads/vocab/expert), the other large dim is FSDP-sharded
    over the data axis when divisible.
    """
    dims: list = [None] * len(shape)
    start = 1 if stacked and len(shape) > 1 else 0
    body = list(range(start, len(shape)))
    if not body:
        return P(*dims)

    lname = path.lower()

    def assign(idx: int, logical: str) -> bool:
        ax = rules.resolve(logical)
        if ax is not None and dims[idx] is None and _divides(mesh, ax, shape[idx]):
            dims[idx] = ax
            return True
        return False

    # Role-specific TP axis.
    if "embed" in lname or "unembed" in lname or "lm_head" in lname:
        assign(body[0], "vocab")                  # (V, D) vocab-sharded
    elif "expert" in lname and len(body) >= 2:
        assign(body[0], "expert")                 # (E, ...) expert-parallel
        # FSDP the reduction dim of the expert matrices.
        if len(body) >= 3:
            assign(body[1], "fsdp")
    elif len(body) >= 2:
        assign(body[-1], "ff" if ("mlp" in lname or "ffn" in lname or "up" in lname
                                  or "gate" in lname) else "heads")
        assign(body[0], "fsdp")
    elif len(body) == 1 and shape[body[0]] >= 1024:
        assign(body[0], "fsdp")
    return P(*dims)


def tree_param_specs(shapes_tree, mesh: Mesh, rules: AxisRules):
    """Map a pytree of ShapeDtypeStructs to NamedShardings."""
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(shapes_tree)
    specs = []
    for path, leaf in paths_leaves:
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        specs.append(NamedSharding(mesh, param_spec(pstr, leaf.shape, mesh, rules)))
    return jax.tree_util.tree_unflatten(treedef, specs)
