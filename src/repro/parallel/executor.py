"""Learner-side executors for stacked inner-search dispatch.

The actor/learner split of the co-design stack: the *learner* process owns
every outer GP, acquisition, and session state machine; *executors* decide
where the embarrassingly-parallel inner work -- whole stacked k*L-run
software searches, packaged as pickle-safe `FanoutSearchSpec`s -- actually
runs.  Content-derived probe seeds (`CodesignEngine.probe_seed`) make
evaluation order and placement free variables, so moving a spec between
processes provably cannot change results; worker-count invariance against
the goldens is pinned in `tests/test_executor.py`.

Two implementations share one small interface (`submit`/`ready`/`run`/
`close`, see `Executor`):

  `InlineExecutor`   runs every spec synchronously in the learner process.
                     Zero overhead, zero processes -- the historical
                     behavior, and the default.
  `ProcessExecutor`  a pool of persistent spawn-started worker processes
                     (`repro.parallel.workers.worker_main`) pulling specs
                     from a task queue.  Each submitted spec is split into
                     per-worker chunks (`ExecutorConfig.chunk_items`) and
                     reassembled in item order.  NumPy evaluation backend
                     first; the spec/queue interface is deliberately
                     placement-agnostic so a jax multi-device `shard_map`
                     executor can drop in behind the same four methods.

Spawn, never fork: a forked child would inherit the parent's jax runtime
and x64 globals (see `workers.py`, which asserts the invariant).
"""

from __future__ import annotations

import dataclasses
import multiprocessing as mp
import queue as _queue
from typing import Any

from repro.core.config import ExecutorConfig
from repro.parallel import workers as _workers


class Executor:
    """Interface: where a `FanoutSearchSpec` runs.

    submit(job_id, spec)   enqueue one spec; results surface via `ready`
    ready(block=False)     completed jobs as `[(job_id, entries), ...]`,
                           oldest first; block=True waits until at least one
                           job completes (no-op when nothing is in flight)
    run(spec)              synchronous convenience: submit + wait, returning
                           the entries directly (other in-flight jobs keep
                           their results queued for `ready`)
    close()                stop workers, if any; idempotent
    """

    kind = "base"

    def submit(self, job_id, spec) -> None:
        raise NotImplementedError

    def ready(self, block: bool = False) -> list[tuple[Any, list]]:
        raise NotImplementedError

    def run(self, spec) -> list:
        raise NotImplementedError

    def close(self) -> None:
        raise NotImplementedError


class InlineExecutor(Executor):
    """Run every spec synchronously in the calling (learner) process."""

    kind = "inline"

    def __init__(self) -> None:
        self._finished: list[tuple[Any, list]] = []

    def submit(self, job_id, spec) -> None:
        self._finished.append((job_id, spec.run()))

    def ready(self, block: bool = False) -> list[tuple[Any, list]]:
        out, self._finished = self._finished, []
        return out

    def run(self, spec) -> list:
        return spec.run()

    def close(self) -> None:
        pass


def _chunk_spec(spec, n_workers: int, chunk_items: int) -> list:
    """Split one spec into item-contiguous chunks (order-preserving).

    chunk_items <= 0 splits evenly across the pool.  An unsplit spec keeps
    its `pad_to` (the bucketed compile-cache hint only helps a whole stack);
    chunks drop it -- padding replays run 0 and is sliced off, so presence
    or absence never changes returned entries.
    """
    n = len(spec.items)
    if chunk_items <= 0:
        chunk_items = max(1, -(-n // max(1, n_workers)))
    if chunk_items >= n:
        return [spec]
    return [dataclasses.replace(spec, items=spec.items[i:i + chunk_items],
                                seeds=spec.seeds[i:i + chunk_items],
                                pad_to=None)
            for i in range(0, n, chunk_items)]


class ProcessExecutor(Executor):
    """Persistent spawn-started worker pool behind two mp queues.

    Workers start lazily on first use and survive across jobs (one-time
    interpreter + import cost per worker, amortized over the pool's life).
    Chunk results are reassembled by (job_id, chunk_idx) in item order, so a
    job's entries come back exactly as an inline run would return them.
    Worker exceptions re-raise in the learner with the worker traceback.
    """

    kind = "process"

    def __init__(self, n_workers: int = 0, chunk_items: int = 0) -> None:
        self.n_workers = n_workers or ExecutorConfig().resolve_workers()
        self.chunk_items = chunk_items
        self._ctx = mp.get_context("spawn")
        self._procs: list = []
        self._tq = self._rq = None
        self._njobs = 0
        # job_id -> {"n": chunk count, "parts": {chunk_idx: payload}}
        self._pending: dict[Any, dict] = {}
        self._finished: list[tuple[Any, list]] = []

    # --- pool lifecycle ---------------------------------------------------------

    def _ensure_started(self) -> None:
        if self._procs:
            return
        self._tq = self._ctx.Queue()
        self._rq = self._ctx.Queue()
        for _ in range(self.n_workers):
            p = self._ctx.Process(target=_workers.worker_main,
                                  args=(self._tq, self._rq), daemon=True)
            p.start()
            self._procs.append(p)

    def close(self) -> None:
        if not self._procs:
            return
        for _ in self._procs:
            self._tq.put(None)
        for p in self._procs:
            p.join(timeout=30)
            if p.is_alive():
                p.terminate()
                p.join(timeout=5)
        self._procs = []
        for q in (self._tq, self._rq):
            q.close()
            q.cancel_join_thread()
        self._tq = self._rq = None
        self._pending.clear()

    def _check_alive(self) -> None:
        dead = [p for p in self._procs if not p.is_alive()]
        if dead and self._pending:
            codes = [p.exitcode for p in dead]
            raise RuntimeError(
                f"{len(dead)} executor worker(s) died (exit codes {codes}) "
                "with work in flight")

    # --- result plumbing --------------------------------------------------------

    def _accept(self, msg) -> None:
        jid, idx, status, payload = msg
        if status == "error":
            err, tb = payload
            raise RuntimeError(
                f"executor worker task failed: {err}\n--- worker traceback "
                f"---\n{tb}")
        job = self._pending[jid]
        job["parts"][idx] = payload
        if len(job["parts"]) == job["n"]:
            del self._pending[jid]
            if job.get("raw"):  # single-part non-list payload (probe)
                self._finished.append((jid, job["parts"][0]))
            else:
                self._finished.append(
                    (jid,
                     [e for i in range(job["n"]) for e in job["parts"][i]]))

    def _drain(self) -> None:
        while True:
            try:
                msg = self._rq.get(False)
            except _queue.Empty:
                return
            self._accept(msg)

    def _pump_until(self, pred) -> None:
        self._drain()
        while not pred():
            if not self._pending:
                raise RuntimeError(
                    "executor wait condition cannot be satisfied: no work "
                    "in flight")
            try:
                msg = self._rq.get(True, 1.0)
            except _queue.Empty:
                self._check_alive()
                continue
            self._accept(msg)

    # --- Executor interface -----------------------------------------------------

    def submit(self, job_id, spec) -> None:
        if job_id in self._pending:
            raise ValueError(f"job id {job_id!r} already in flight")
        self._ensure_started()
        chunks = _chunk_spec(spec, self.n_workers, self.chunk_items)
        self._pending[job_id] = {"n": len(chunks), "parts": {}}
        for idx, chunk in enumerate(chunks):
            self._tq.put(("search", job_id, idx, chunk))

    def ready(self, block: bool = False) -> list[tuple[Any, list]]:
        if block and not self._finished and self._pending:
            self._pump_until(lambda: bool(self._finished))
        else:
            self._drain()
        out, self._finished = self._finished, []
        return out

    def _wait(self, jid) -> Any:
        while True:
            for i, (j, payload) in enumerate(self._finished):
                if j == jid:
                    del self._finished[i]
                    return payload
            self._pump_until(
                lambda: any(j == jid for j, _ in self._finished))

    def run(self, spec) -> list:
        jid = ("_run", self._njobs)
        self._njobs += 1
        self.submit(jid, spec)
        return self._wait(jid)

    def probe(self) -> dict:
        """State snapshot from one worker (the no-jax regression surface)."""
        self._ensure_started()
        jid = ("_probe", self._njobs)
        self._njobs += 1
        self._pending[jid] = {"n": 1, "parts": {}, "raw": True}
        self._tq.put(("probe", jid, 0, None))
        return self._wait(jid)


def make_executor(cfg: ExecutorConfig | None = None) -> Executor:
    """Build the executor an `ExecutorConfig` describes."""
    cfg = cfg if cfg is not None else ExecutorConfig()
    if cfg.kind == "inline":
        return InlineExecutor()
    return ProcessExecutor(n_workers=cfg.resolve_workers(),
                           chunk_items=cfg.chunk_items)
