"""Fault tolerance & elasticity for the training loop.

Three mechanisms, all exercised by tests/test_runtime.py:

* `ResilientLoop` -- wraps the step function; on failure (device error,
  preemption signal, injected fault) it restores the latest checkpoint and
  replays from there.  Because the data pipeline is a pure function of step,
  replay is bit-deterministic.
* `StragglerMonitor` -- per-step wall-time EMA + z-score; flags outlier steps
  (on real clusters this feeds the scheduler to hot-swap slow hosts; here it
  logs and counts).
* `elastic_remesh` -- re-plans the mesh for a changed device count and
  re-lowers the step function; state is resharded by device_put onto the new
  mesh (elastic scale-up/down between checkpoint boundaries).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import numpy as np

from repro.checkpoint import checkpoint as ckpt


class InjectedFault(RuntimeError):
    """Stand-in for a device failure / preemption in tests and examples."""


@dataclasses.dataclass
class StragglerMonitor:
    alpha: float = 0.1
    z_threshold: float = 4.0
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    flagged: int = 0

    def observe(self, dt: float) -> bool:
        self.n += 1
        if self.n == 1:
            self.mean, self.var = dt, 0.0
            return False
        z = (dt - self.mean) / (np.sqrt(self.var) + 1e-9)
        is_straggler = self.n > 5 and z > self.z_threshold
        if is_straggler:
            self.flagged += 1
        else:  # don't poison the EMA with outliers
            d = dt - self.mean
            self.mean += self.alpha * d
            self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        return is_straggler


@dataclasses.dataclass
class ResilientLoop:
    """Checkpoint/restart training driver."""

    step_fn: Callable          # (state, batch) -> (state, metrics); may raise
    source: object             # .batch(step) -> host batch
    ckpt_dir: str
    save_every: int = 50
    max_retries: int = 5

    def run(self, state, start_step: int, num_steps: int,
            fault_schedule: set | None = None, log: Callable | None = None):
        """Runs steps [start_step, start_step+num_steps); `fault_schedule` is a
        set of step indices at which an InjectedFault fires once (tests)."""
        saver = ckpt.AsyncCheckpointer(self.ckpt_dir)
        monitor = StragglerMonitor()
        initial_state = state
        fired: set = set()
        step = start_step
        retries = 0
        metrics_log = []
        while step < start_step + num_steps:
            try:
                if fault_schedule and step in fault_schedule and step not in fired:
                    fired.add(step)
                    raise InjectedFault(f"injected fault at step {step}")
                t0 = time.perf_counter()
                batch = self.source.batch(step)
                state, metrics = self.step_fn(state, batch)
                dt = time.perf_counter() - t0
                straggler = monitor.observe(dt)
                metrics = dict(metrics, step=step, dt=dt, straggler=straggler)
                metrics_log.append(metrics)
                if log:
                    log(metrics)
                step += 1
                retries = 0
                if step % self.save_every == 0:
                    saver.save(step, state)
            except (InjectedFault, RuntimeError) as e:
                retries += 1
                if retries > self.max_retries:
                    raise
                saver.wait()  # an in-flight save may land the newest checkpoint
                restored = ckpt.latest_step(self.ckpt_dir)
                if restored is not None:
                    state, rstep = ckpt.restore(self.ckpt_dir, state)
                    step = rstep
                else:
                    state, step = initial_state, start_step  # replay from scratch
                if log:
                    log({"event": "restart", "from_step": step, "error": str(e)})
        saver.save(step, state)
        saver.wait()
        return state, step, metrics_log, monitor


def elastic_remesh(make_mesh: Callable[[int], jax.sharding.Mesh],
                   lower_fn: Callable, state, new_device_count: int):
    """Re-plan for a changed device count: build the new mesh, re-lower the
    step function, and reshard the state onto it."""
    mesh = make_mesh(new_device_count)
    lowered = lower_fn(mesh)
    state = jax.device_put(state, jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec()))
    return mesh, lowered, state
