"""Co-design as a service: a request-queue driver over `SearchSession`s.

Clients submit co-design requests (layers + a `CodesignConfig`, as objects or
JSON); the service admits up to `ServiceConfig.max_slots` of them as live
`SearchSession`s and advances all of them in lockstep ticks, the slot-admission
shape of `launch/serve.py`'s decode batch.  Each tick:

  1. admit queued requests into free slots;
  2. collect every active session's `pending()` work -- the (hw, layer) inner
     software searches its next outer trial needs, with content-derived seeds;
  3. resolve what it can from the persistent `DesignStore` (exact replays,
     keyed by `design_key`), deduplicate identical searches across requests,
     and fuse the remainder into ONE cross-request stacked
     `optimize_software_fanout` dispatch per fuse group (requests whose
     search config + backend agree share a group; `fuse=False` keeps one
     dispatch per request -- the ablation baseline);
  4. prefill each owning session's cache with the results, publish them to
     the store, and `step()` every session one outer trial.

Because probe seeds are content-derived and `SearchSession.pending()` is
trajectory-neutral (the outer plan is cached until `step()` commits it), a
request's result is bit-identical to running its engine standalone -- fusion
and the store move inner-search work across requests and across runs, never
change it.  Two scope notes: cross-request stacking inherits the stacked GP's
Cholesky-regime contract (see tests/test_layer_batch.py), and under
`strategy="sequential"` with `hw.prune != "off"` the standalone path stops a
probe's per-layer searches at the first infeasible layer while the service
prefills all of them, which can shift WHEN the bound gate censors -- the
batched strategies (layer_batched/probe_fanout/speculative) search all layers
inline too and carry no such caveat.
"""

from __future__ import annotations

import dataclasses
import json
import time

from repro.core.config import CodesignConfig, ServiceConfig
from repro.core.nested import (CodesignEngine, CoDesignResult, SearchSession,
                               _cache_entry, optimize_software_fanout)
from repro.service.store import DesignStore, design_key
from repro.timeloop.workloads import MODEL_LAYERS, ConvLayer


@dataclasses.dataclass(frozen=True)
class ServiceRequest:
    """One co-design request: the layers to co-design for and the full search
    config.  `rid=None` lets the service assign one at submission."""

    layers: tuple[ConvLayer, ...]
    config: CodesignConfig = dataclasses.field(default_factory=CodesignConfig)
    rid: str | None = None

    def __post_init__(self) -> None:
        if not self.layers:
            raise ValueError("request has no layers")
        object.__setattr__(self, "layers", tuple(self.layers))

    # --- JSON queue surface -------------------------------------------------------

    @classmethod
    def from_dict(cls, d: dict) -> "ServiceRequest":
        """`layers` is either a model name from `MODEL_LAYERS` ("dqn") or a
        list of `ConvLayer` field dicts; `config` a `CodesignConfig` dict
        (sections may be omitted)."""
        d = dict(d)
        layers = d.pop("layers")
        if isinstance(layers, str):
            if layers not in MODEL_LAYERS:
                raise ValueError(f"unknown model {layers!r}; "
                                 f"known: {sorted(MODEL_LAYERS)}")
            layers = MODEL_LAYERS[layers]
        else:
            layers = [ConvLayer(**ld) if isinstance(ld, dict) else ld
                      for ld in layers]
        config = d.pop("config", None)
        if isinstance(config, dict):
            config = CodesignConfig.from_dict(config)
        elif config is None:
            config = CodesignConfig()
        rid = d.pop("rid", None)
        if d:
            raise ValueError(f"unknown request key(s) {sorted(d)}")
        return cls(layers=tuple(layers), config=config, rid=rid)

    def to_dict(self) -> dict:
        return {
            "rid": self.rid,
            "layers": [dataclasses.asdict(layer) for layer in self.layers],
            "config": self.config.to_dict(),
        }

    @classmethod
    def from_json(cls, s: str) -> "ServiceRequest":
        return cls.from_dict(json.loads(s))

    def to_json(self, **json_kw) -> str:
        json_kw.setdefault("sort_keys", True)
        return json.dumps(self.to_dict(), **json_kw)


@dataclasses.dataclass
class ServiceResponse:
    rid: str
    result: CoDesignResult   # stats carry store_hits/store_misses/latency_s
    latency_s: float         # admission -> completion wall clock
    ticks: int               # scheduler ticks the request was live


class _Slot:
    """One admitted request: its engine + live session and per-request
    accounting."""

    def __init__(self, request: ServiceRequest, engine: CodesignEngine,
                 session: SearchSession):
        self.request = request
        self.engine = engine
        self.session = session
        self.t0 = time.perf_counter()
        self.ticks = 0
        self.store_hits = 0
        self.store_misses = 0


class CodesignService:
    """The request-queue driver.  `submit()` requests (objects, dicts, or JSON
    strings), then `run()` to drain the queue; per-request `ServiceResponse`s
    come back keyed by rid, each bit-identical to the standalone
    `CodesignEngine(config).run(layers)` result (see the module docstring for
    the two scope notes)."""

    def __init__(self, config: ServiceConfig | None = None,
                 store: DesignStore | None = None):
        self.config = config if config is not None else ServiceConfig()
        if store is None and self.config.store_dir is not None:
            store = DesignStore(self.config.store_dir)
        self.store = store
        self._queue: list[ServiceRequest] = []
        self._slots: list[_Slot] = []
        self._next_rid = 0
        # service-level accounting (per-request numbers land in result.stats)
        self.stats = {"ticks": 0, "fused_dispatches": 0, "fused_items": 0,
                      "deduped_items": 0}

    def submit(self, request: ServiceRequest | dict | str) -> str:
        """Enqueue a request (admitted when a slot frees up); returns its rid,
        assigning `"r<n>"` when the request carries none."""
        if isinstance(request, str):
            request = ServiceRequest.from_json(request)
        elif isinstance(request, dict):
            request = ServiceRequest.from_dict(request)
        if request.rid is None:
            request = dataclasses.replace(request, rid=f"r{self._next_rid}")
        self._next_rid += 1
        if any(r.rid == request.rid for r in self._queue) or \
                any(s.request.rid == request.rid for s in self._slots):
            raise ValueError(f"duplicate request id {request.rid!r}")
        self._queue.append(request)
        return request.rid

    def run(self) -> dict[str, ServiceResponse]:
        """Drain the queue: tick until every submitted request completed."""
        responses: dict[str, ServiceResponse] = {}
        while self._queue or self._slots:
            self._tick(responses)
        return responses

    # --- internals ----------------------------------------------------------------

    def _admit(self) -> None:
        while self._queue and len(self._slots) < self.config.max_slots:
            req = self._queue.pop(0)
            cfg = req.config
            if cfg.engine.cache_entries == 0 and self.config.cache_entries:
                # service memory bound: long-lived processes must not grow the
                # (hw, layer) cache without limit unless the request insists
                cfg = dataclasses.replace(cfg, engine=dataclasses.replace(
                    cfg.engine, cache_entries=self.config.cache_entries))
            engine = CodesignEngine(cfg)
            self._slots.append(_Slot(req, engine, engine.session(req.layers)))

    def _fuse_key(self, slot: _Slot):
        """Requests may share one stacked dispatch iff every knob their inner
        searches consume agrees -- the same fields `design_key` hashes."""
        eng = slot.engine.config.engine
        return (dataclasses.astuple(slot.engine.config.sw),
                eng.resolve_backend(), eng.pallas_mode, eng.batched,
                eng.gp_refit_every)

    def _tick(self, responses: dict[str, ServiceResponse]) -> None:
        self.stats["ticks"] += 1
        self._admit()

        # Gather every session's pending inner searches; resolve store hits,
        # dedup identical searches across requests (equal design_key implies
        # equal fuse key: the key hashes the same fields), fuse the rest.
        owners: dict[str, list[tuple[_Slot, tuple]]] = {}
        groups: dict[tuple, dict] = {}
        for slot in self._slots:
            items, seeds = slot.session.pending()
            sw_cfg = slot.engine.config.sw
            eng_cfg = slot.engine.config.engine
            for item, seed in zip(items, seeds):
                key = design_key(item[0], item[1], sw_cfg, eng_cfg, seed)
                if key in owners:  # another request queued this exact search
                    owners[key].append((slot, item))
                    self.stats["deduped_items"] += 1
                    continue
                if self.store is not None:
                    entry = self.store.get(key)
                    if entry is not None:
                        slot.store_hits += 1
                        slot.engine.cache[item] = entry
                        continue
                    slot.store_misses += 1
                owners[key] = [(slot, item)]
                fk = (self._fuse_key(slot) if self.config.fuse
                      else ("slot", slot.request.rid))
                g = groups.setdefault(fk, {"items": [], "seeds": [],
                                           "keys": [], "slot": slot, "q": 1})
                g["items"].append(item)
                g["seeds"].append(seed)
                g["keys"].append(key)
                g["q"] = max(g["q"], len(dict.fromkeys(slot.engine._layers)))

        # One stacked multi-run dispatch per fuse group: on the JAX backend
        # every BO round of ALL fused requests' searches is a single fused
        # device program.  Pad to a whole number of probes (the speculative
        # strategy's bucketing) so the compiled per-round width stays stable
        # as sessions' per-tick item counts fluctuate.
        for g in groups.values():
            cfg = g["slot"].engine.config
            rs = optimize_software_fanout(
                g["items"], cfg.sw, seeds=g["seeds"], engine=cfg.engine,
                pad_to=-(-len(g["items"]) // g["q"]) * g["q"])
            self.stats["fused_dispatches"] += 1
            self.stats["fused_items"] += len(g["items"])
            for (hw, layer), key, r in zip(g["items"], g["keys"], rs):
                entry = _cache_entry(hw, layer, r)
                for slot, item in owners[key]:
                    slot.engine.cache[item] = entry
                if self.store is not None:
                    self.store.put(key, entry)

        # Advance every session one outer stage; retire completed requests.
        still = []
        for slot in self._slots:
            slot.ticks += 1
            if slot.session.step():
                still.append(slot)
            else:
                responses[slot.request.rid] = self._finish(slot)
        self._slots = still

    def _finish(self, slot: _Slot) -> ServiceResponse:
        latency = time.perf_counter() - slot.t0
        result = slot.session.result()
        result.stats.update(store_hits=slot.store_hits,
                            store_misses=slot.store_misses,
                            latency_s=latency, ticks=slot.ticks)
        return ServiceResponse(rid=slot.request.rid, result=result,
                               latency_s=latency, ticks=slot.ticks)
