"""Co-design as a service: a request-queue driver over `SearchSession`s.

Clients submit co-design requests (layers + a `CodesignConfig`, as objects or
JSON); the service admits up to `ServiceConfig.max_slots` of them as live
`SearchSession`s and advances all of them in lockstep ticks, the slot-admission
shape of `launch/serve.py`'s decode batch.  Each tick:

  1. admit queued requests into free slots (higher `priority` first, FIFO
     within a priority);
  2. collect every un-parked session's `pending()` work -- the (hw, layer)
     inner software searches its next outer trial needs, with
     content-derived seeds;
  3. resolve what it can from the persistent `DesignStore` (exact replays,
     keyed by `design_key`), deduplicate identical searches against
     everything queued or already in flight, and fuse the remainder into ONE
     cross-request stacked dispatch per fuse group (requests whose search
     config + backend agree share a group; `fuse=False` keeps one dispatch
     per request -- the ablation baseline), submitted to the service's
     executor (`repro.parallel`) as a pickle-safe `FanoutSearchSpec`;
  4. collect resolved dispatches (blocking only when every live session is
     parked), prefill each owning session's cache, publish entries to the
     store, and `step()` each session whose work resolved one outer trial.

With the default inline executor every dispatch resolves in its own tick and
the schedule is exactly the historical synchronous one.  With
`ExecutorConfig(kind="process")` the ticks *overlap*: sessions whose pending
work is still in flight park while sessions with resolved results step
immediately, so one slow fuse group no longer gates every other request --
the learner process keeps all outer GP/acquisition state machines hot while
worker processes run the stacked inner searches.

Because probe seeds are content-derived and `SearchSession.pending()` is
trajectory-neutral (the outer plan is cached until `step()` commits it), a
request's result is bit-identical to running its engine standalone -- fusion
and the store move inner-search work across requests and across runs, never
change it.  Two scope notes: cross-request stacking inherits the stacked GP's
Cholesky-regime contract (see tests/test_layer_batch.py), and under
`strategy="sequential"` with `hw.prune != "off"` the standalone path stops a
probe's per-layer searches at the first infeasible layer while the service
prefills all of them, which can shift WHEN the bound gate censors -- the
batched strategies (layer_batched/probe_fanout/speculative) search all layers
inline too and carry no such caveat.
"""

from __future__ import annotations

import dataclasses
import json
import time

import numpy as np

from repro.core.bo import FanoutSearchSpec
from repro.core.config import CodesignConfig, ServiceConfig
from repro.core.nested import CodesignEngine, CoDesignResult, SearchSession
from repro.parallel.executor import make_executor
from repro.service.store import (DesignStore, TrialHistory, design_key,
                                 history_key)
from repro.timeloop.model import evaluate
from repro.timeloop.workloads import ConvLayer
from repro.workloads.portfolio import (PortfolioConfig, PortfolioSession,
                                       make_portfolio_engine)
from repro.workloads.zoo import resolve_workload


@dataclasses.dataclass(frozen=True)
class ServiceRequest:
    """One co-design request: the layers to co-design for and the full search
    config.  `rid=None` lets the service assign one at submission.

    `priority` (higher first) orders admission from the queue and the per-tick
    fuse-group submission to the executor; within one priority, admission
    stays FIFO.  Priorities only reorder WHEN work runs -- content-derived
    seeds keep every request's result identical either way.

    A request carries either `layers` OR a `portfolio` (a `PortfolioConfig`
    naming member workload sets + traffic weights): portfolio requests are
    served as `PortfolioSession`s over the union of their members' layers."""

    layers: tuple[ConvLayer, ...] = ()
    config: CodesignConfig = dataclasses.field(default_factory=CodesignConfig)
    rid: str | None = None
    priority: int = 0
    portfolio: PortfolioConfig | None = None

    def __post_init__(self) -> None:
        if self.portfolio is not None:
            if not isinstance(self.portfolio, PortfolioConfig):
                raise ValueError(
                    f"portfolio must be a PortfolioConfig, got "
                    f"{self.portfolio!r}")
            if self.layers:
                raise ValueError(
                    "pass either layers or portfolio, not both (a portfolio "
                    "request searches the union of its members' layers)")
            if self.config.hw.prune != "off":
                raise ValueError(
                    "portfolio requests require config.hw.prune='off' (the "
                    "EDP lower-bound gate is incompatible with the weighted "
                    "member objective)")
        elif not self.layers:
            raise ValueError("request has no layers")
        if not isinstance(self.priority, int) or isinstance(self.priority,
                                                            bool):
            raise ValueError(
                f"priority must be an int, got {self.priority!r}")
        object.__setattr__(self, "layers", tuple(self.layers))

    # --- JSON queue surface -------------------------------------------------------

    @classmethod
    def from_dict(cls, d: dict) -> "ServiceRequest":
        """`layers` is either a workload name -- a paper set ("dqn") or a zoo
        model ("llama4_maverick_400b_a17b") -- or a list of `ConvLayer` field
        dicts; `portfolio` a `PortfolioConfig` dict (replaces `layers`);
        `config` a `CodesignConfig` dict (sections may be omitted)."""
        d = dict(d)
        layers = d.pop("layers", None)
        if isinstance(layers, str):
            layers = resolve_workload(layers)  # raises listing known names
        elif layers is not None:
            layers = [ConvLayer(**ld) if isinstance(ld, dict) else ld
                      for ld in layers]
        portfolio = d.pop("portfolio", None)
        if isinstance(portfolio, dict):
            portfolio = PortfolioConfig.from_dict(portfolio)
        config = d.pop("config", None)
        if isinstance(config, dict):
            config = CodesignConfig.from_dict(config)
        elif config is None:
            config = CodesignConfig()
        rid = d.pop("rid", None)
        priority = d.pop("priority", 0)
        if d:
            raise ValueError(f"unknown request key(s) {sorted(d)}")
        return cls(layers=tuple(layers or ()), config=config, rid=rid,
                   priority=priority, portfolio=portfolio)

    def to_dict(self) -> dict:
        return {
            "rid": self.rid,
            "priority": self.priority,
            "layers": [dataclasses.asdict(layer) for layer in self.layers],
            "config": self.config.to_dict(),
            "portfolio": (self.portfolio.to_dict()
                          if self.portfolio is not None else None),
        }

    @classmethod
    def from_json(cls, s: str) -> "ServiceRequest":
        return cls.from_dict(json.loads(s))

    def to_json(self, **json_kw) -> str:
        json_kw.setdefault("sort_keys", True)
        return json.dumps(self.to_dict(), **json_kw)


@dataclasses.dataclass
class ServiceResponse:
    rid: str
    result: CoDesignResult   # stats carry store_hits/store_misses/latency_s
    latency_s: float         # admission -> completion wall clock
    ticks: int               # scheduler ticks the request was live


class _Slot:
    """One admitted request: its engine + live session and per-request
    accounting.  `waiting` holds the design keys of this session's pending
    searches that are still in flight on the executor -- a slot with a
    non-empty `waiting` set is *parked*: it neither re-gathers nor steps
    until every key resolves (the overlapped-tick mechanism)."""

    def __init__(self, request: ServiceRequest, engine: CodesignEngine,
                 session: SearchSession):
        self.request = request
        self.engine = engine
        self.session = session
        self.t0 = time.perf_counter()
        self.ticks = 0
        self.store_hits = 0
        self.store_misses = 0
        self.waiting: set[str] = set()
        # Cross-run transfer accounting: whether this request opted into
        # warm starts (hw.warm_start), how many approximate store hits
        # seeded its inner searches, and how many history rows its outer GP
        # consumed.
        self.warm_start = False
        self.warm_hits = 0
        self.prior_rows = 0


class CodesignService:
    """The request-queue driver.  `submit()` requests (objects, dicts, or JSON
    strings), then `run()` to drain the queue; per-request `ServiceResponse`s
    come back keyed by rid, each bit-identical to the standalone
    `CodesignEngine(config).run(layers)` result (see the module docstring for
    the two scope notes)."""

    def __init__(self, config: ServiceConfig | None = None,
                 store: DesignStore | None = None, executor=None):
        self.config = config if config is not None else ServiceConfig()
        if store is None and self.config.store_dir is not None:
            store = DesignStore(self.config.store_dir)
        self.store = store
        # Cross-run trial history (`ServiceConfig.history_dir`): every
        # non-portfolio request logs its finished outer trials here, and
        # requests with `hw.warm_start` replay the matching workload set's
        # rows into their outer GP.
        self.history = (TrialHistory(self.config.history_dir)
                        if self.config.history_dir is not None else None)
        # design_key -> (mapping, edp): approximate-store-hit warm starts
        # resolved this tick, consumed at collect time by warm_start slots
        # (the stored entry stays the PURE search result -- a store hit must
        # remain an exact replay for every other consumer).
        self._warm: dict[str, tuple] = {}
        # The executor every fused dispatch runs on: injected (shared pools
        # amortize worker start-up across services) or built from
        # `ServiceConfig.executor` and owned -- `close()` shuts an owned
        # pool down.
        self._owns_executor = executor is None
        self.executor = executor if executor is not None \
            else make_executor(self.config.executor)
        self._queue: list[ServiceRequest] = []
        self._slots: list[_Slot] = []
        self._next_rid = 0
        self._next_job = 0
        # design_key -> [(slot, item), ...] for every unresolved search, and
        # job id -> fuse group for every dispatch in flight.  Both persist
        # across ticks: with a process executor, a tick's dispatches may
        # resolve several ticks later while other sessions keep stepping.
        self._owners: dict[str, list[tuple[_Slot, tuple]]] = {}
        self._inflight: dict[int, dict] = {}
        # service-level accounting (per-request numbers land in result.stats)
        self.stats = {"ticks": 0, "fused_dispatches": 0, "fused_items": 0,
                      "deduped_items": 0}

    def close(self) -> None:
        """Shut down an owned executor pool (no-op for injected executors);
        idempotent."""
        if self._owns_executor:
            self.executor.close()

    def __enter__(self) -> "CodesignService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def submit(self, request: ServiceRequest | dict | str) -> str:
        """Enqueue a request (admitted when a slot frees up); returns its rid,
        assigning `"r<n>"` when the request carries none."""
        if isinstance(request, str):
            request = ServiceRequest.from_json(request)
        elif isinstance(request, dict):
            request = ServiceRequest.from_dict(request)
        if request.rid is None:
            request = dataclasses.replace(request, rid=f"r{self._next_rid}")
        self._next_rid += 1
        if any(r.rid == request.rid for r in self._queue) or \
                any(s.request.rid == request.rid for s in self._slots):
            raise ValueError(f"duplicate request id {request.rid!r}")
        self._queue.append(request)
        return request.rid

    def run(self) -> dict[str, ServiceResponse]:
        """Drain the queue: tick until every submitted request completed."""
        responses: dict[str, ServiceResponse] = {}
        while self._queue or self._slots:
            self._tick(responses)
        return responses

    # --- internals ----------------------------------------------------------------

    def _admit(self) -> None:
        # Higher priority admits first; the sort is stable, so submission
        # order (FIFO) breaks ties exactly as before priorities existed.
        self._queue.sort(key=lambda r: -r.priority)
        while self._queue and len(self._slots) < self.config.max_slots:
            req = self._queue.pop(0)
            cfg = req.config
            if cfg.engine.cache_entries == 0 and self.config.cache_entries:
                # service memory bound: long-lived processes must not grow the
                # (hw, layer) cache without limit unless the request insists
                cfg = dataclasses.replace(cfg, engine=dataclasses.replace(
                    cfg.engine, cache_entries=self.config.cache_entries))
            if req.portfolio is not None:
                engine = make_portfolio_engine(cfg, executor=self.executor)
                session = PortfolioSession(engine, req.portfolio)
                slot = _Slot(req, engine, session)
            else:
                engine = CodesignEngine(cfg, executor=self.executor)
                prior = trial_log = None
                if self.history is not None:
                    # Always log (cold runs feed future warm ones); only
                    # consume when the request opted in.
                    hkey = history_key(req.layers, cfg.hw, cfg.sw, cfg.engine)
                    trial_log = (lambda row, _hk=hkey:
                                 self.history.append(_hk, row))
                    if cfg.hw.warm_start:
                        prior = self.history.load(
                            hkey, max_rows=cfg.hw.warm_start_rows)
                session = engine.session(req.layers, prior=prior or None,
                                         trial_log=trial_log)
                slot = _Slot(req, engine, session)
                slot.warm_start = cfg.hw.warm_start
                slot.prior_rows = len(prior) if prior else 0
            self._slots.append(slot)

    def _transplant(self, slot: _Slot, item: tuple):
        """Approximate store hit for one (hw, layer) search: the nearest
        stored hardware point's best mapping for the same layer, re-evaluated
        through the true model ON THE TARGET hardware.  Returns an exact
        `(mapping, edp)` cache entry (or None: no neighbor, or its mapping is
        invalid here) -- never a replayed neighbor result, so everything this
        serves carries an exact EDP."""
        hw, layer = item
        near = self.store.nearest(hw, layer)
        if near is None:
            return None
        _, mapping, _ = near
        ev = evaluate(hw, mapping, layer)
        if not np.isfinite(ev.edp):
            return None  # neighbor's mapping doesn't even fit this hardware
        slot.warm_hits += 1
        return (mapping, float(ev.edp))

    def _fuse_key(self, slot: _Slot):
        """Requests may share one stacked dispatch iff every knob their inner
        searches consume agrees -- the same fields `design_key` hashes."""
        eng = slot.engine.config.engine
        return (dataclasses.astuple(slot.engine.config.sw),
                eng.resolve_backend(), eng.pallas_mode, eng.batched,
                eng.gp_refit_every)

    def _tick(self, responses: dict[str, ServiceResponse]) -> None:
        self.stats["ticks"] += 1
        self._admit()

        # Gather each un-parked session's pending inner searches (higher
        # request priority gathers -- and therefore submits -- first);
        # resolve store hits, dedup identical searches against everything
        # queued OR already in flight (equal design_key implies equal fuse
        # key: the key hashes the same fields), fuse the rest.  Parked slots
        # are skipped: `pending()` is trajectory-neutral, so their pending
        # work is exactly the in-flight work they are waiting on.
        groups: dict[tuple, dict] = {}
        for slot in sorted(self._slots, key=lambda s: -s.request.priority):
            if slot.waiting:
                continue
            items, seeds = slot.session.pending()
            sw_cfg = slot.engine.config.sw
            eng_cfg = slot.engine.config.engine
            for item, seed in zip(items, seeds):
                key = design_key(item[0], item[1], sw_cfg, eng_cfg, seed)
                if key in self._owners:  # identical search queued/in flight
                    self._owners[key].append((slot, item))
                    slot.waiting.add(key)
                    self.stats["deduped_items"] += 1
                    continue
                if self.store is not None:
                    entry = self.store.get(key)
                    if entry is not None:
                        slot.store_hits += 1
                        slot.engine.cache[item] = entry
                        continue
                    slot.store_misses += 1
                    if slot.warm_start:
                        # Approximate hit: a close stored hardware point's
                        # mapping, re-evaluated exactly on THIS hardware,
                        # competes with the search result at collect time.
                        warm = self._transplant(slot, item)
                        if warm is not None:
                            self._warm[key] = warm
                self._owners[key] = [(slot, item)]
                slot.waiting.add(key)
                fk = (self._fuse_key(slot) if self.config.fuse
                      else ("slot", slot.request.rid))
                g = groups.setdefault(fk, {"items": [], "seeds": [],
                                           "keys": [], "slot": slot, "q": 1})
                g["items"].append(item)
                g["seeds"].append(seed)
                g["keys"].append(key)
                g["q"] = max(g["q"], len(dict.fromkeys(slot.engine._layers)))

        # One stacked multi-run dispatch per fuse group, submitted to the
        # executor (inline: runs now; process: workers pull it while the
        # learner keeps ticking).  On the JAX backend every BO round of ALL
        # fused requests' searches is a single fused device program.  Pad to
        # a whole number of probes (the speculative strategy's bucketing) so
        # the compiled per-round width stays stable as sessions' per-tick
        # item counts fluctuate.
        for g in groups.values():
            cfg = g["slot"].engine.config
            spec = FanoutSearchSpec(
                items=tuple(g["items"]), seeds=tuple(g["seeds"]),
                sw=cfg.sw, engine=cfg.engine,
                pad_to=-(-len(g["items"]) // g["q"]) * g["q"])
            jid = self._next_job
            self._next_job += 1
            self.executor.submit(jid, spec)
            self._inflight[jid] = g
            self.stats["fused_dispatches"] += 1
            self.stats["fused_items"] += len(g["items"])

        # Collect whatever has resolved; block only when every live session
        # is parked (nothing could step anyway).  Each resolved entry
        # prefills every owning session's cache and lands in the store.
        block = bool(self._inflight) and \
            all(s.waiting for s in self._slots)
        for jid, entries in self.executor.ready(block=block):
            g = self._inflight.pop(jid)
            for key, item, entry in zip(g["keys"], g["items"], entries):
                # A transplanted warm start competes with the search result
                # per warm-started owner (both EDPs are exact, so best-of is
                # never worse); the store always receives the PURE search
                # entry -- a store hit stays an exact replay of the search.
                warm = self._warm.pop(key, None)
                for slot, s_item in self._owners.pop(key):
                    e = entry
                    if warm is not None and slot.warm_start \
                            and warm[1] < entry[1]:
                        e = warm
                    slot.engine.cache[s_item] = e
                    slot.waiting.discard(key)
                if self.store is not None:
                    self.store.put(key, entry, hw=item[0], layer=item[1])

        # Advance every session whose results resolved one outer stage;
        # sessions with work still in flight stay parked.  Retire completed
        # requests.
        still = []
        for slot in self._slots:
            if slot.waiting:
                still.append(slot)
                continue
            slot.ticks += 1
            if slot.session.step():
                still.append(slot)
            else:
                responses[slot.request.rid] = self._finish(slot)
        self._slots = still

    def _finish(self, slot: _Slot) -> ServiceResponse:
        latency = time.perf_counter() - slot.t0
        result = slot.session.result()
        result.stats.update(store_hits=slot.store_hits,
                            store_misses=slot.store_misses,
                            warm_hits=slot.warm_hits,
                            prior_rows=slot.prior_rows,
                            latency_s=latency, ticks=slot.ticks)
        if self.store is not None and self.config.store_max_entries:
            # Disk-footprint bound for long-lived services: evict oldest
            # entries beyond the cap as each request retires.
            self.store.prune(self.config.store_max_entries)
        return ServiceResponse(rid=slot.request.rid, result=result,
                               latency_s=latency, ticks=slot.ticks)
