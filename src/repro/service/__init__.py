"""Co-design as a service (paper workloads, many tenants, one device).

`CodesignService` admits co-design requests (layers + `CodesignConfig`, as
objects or JSON) into concurrent `SearchSession` slots, fuses their pending
inner software searches into one cross-request stacked dispatch per tick, and
persists every finished (hw, layer) search in a content-addressed
`DesignStore` so overlapping or repeated workloads skip re-searching.
Per-request results are bit-identical to standalone `CodesignEngine.run`
(see `repro.service.scheduler` for the two scope notes).
"""

from repro.core.config import ExecutorConfig, ServiceConfig
from repro.parallel.executor import (InlineExecutor, ProcessExecutor,
                                     make_executor)
from repro.service.scheduler import (CodesignService, ServiceRequest,
                                     ServiceResponse)
from repro.service.store import (DesignStore, TrialHistory, design_key,
                                 history_key)
from repro.workloads.portfolio import PortfolioConfig

__all__ = [
    "CodesignService",
    "DesignStore",
    "PortfolioConfig",
    "ExecutorConfig",
    "InlineExecutor",
    "ProcessExecutor",
    "ServiceConfig",
    "ServiceRequest",
    "ServiceResponse",
    "TrialHistory",
    "design_key",
    "history_key",
    "make_executor",
]
