"""Persistent design store: content-addressed (hw, layer) inner-search results.

The store is the cross-run sibling of `CodesignEngine`'s in-memory cache: an
entry records the outcome of ONE inner software-mapping search -- the best
mapping found (or infeasibility) and its true model EDP -- under a key that
hashes everything that determines that search bit-for-bit:

    design_key(hw, layer, sw_cfg, engine_cfg, probe_seed)

Probe seeds are already content-derived (`CodesignEngine.probe_seed`), so two
requests that probe the same hardware point under the same search config and
run seed share a key -- and a store hit is an *exact replay* of the search the
engine would run, not an approximation.  The scheduler prefills session
caches from the store before dispatching searches, so repeated or
overlapping workloads skip re-searching entirely.

Layout (one JSON file per entry, fanned out by key prefix):

    <dir>/ab/abcdef...1234.json

Writes reuse the `repro.checkpoint` atomic pattern -- serialize to a
temporary file in the destination directory, then `os.replace` -- so readers
never observe a torn entry and concurrent writers of the same key are safe
(last writer wins with identical bytes; keys are content-addressed).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile

from repro.core.config import EngineConfig, SWSearchConfig
from repro.timeloop.arch import HardwareConfig
from repro.timeloop.mapping import Mapping
from repro.timeloop.workloads import ConvLayer


def design_key(hw: HardwareConfig, layer: ConvLayer,
               sw_cfg: SWSearchConfig, engine_cfg: EngineConfig,
               probe_seed: int) -> str:
    """Stable content hash identifying one (hw, layer) inner search.

    Includes every field that can change the search's result: the hardware
    point, the layer, the full software search config, the engine fields the
    inner `bo_maximize` consumes (resolved backend, refit stride, batched
    protocol, pallas mode), and the probe's content-derived seed.  Engine
    fields that only move work around (strategy, use_cache, hw_*) are
    excluded -- strategies are pinned bit-identical to sequential."""
    eng = (engine_cfg.resolve_backend(), engine_cfg.gp_refit_every,
           engine_cfg.batched, engine_cfg.pallas_mode)
    data = repr((dataclasses.astuple(hw), dataclasses.astuple(layer),
                 dataclasses.astuple(sw_cfg), eng, int(probe_seed))).encode()
    return hashlib.blake2s(data, digest_size=16).hexdigest()


def _encode_entry(entry: tuple[Mapping | None, float]) -> dict:
    mapping, edp = entry
    if mapping is None:
        return {"feasible": False}
    return {
        "feasible": True,
        # float(edp) JSON round-trips exactly (repr serialization), so a
        # warm entry is bit-identical to the search that produced it.
        "edp": float(edp),
        "mapping": {
            "factors": [list(level) for level in mapping.factors],
            "order_lb": list(mapping.order_lb),
            "order_gb": list(mapping.order_gb),
            "order_dram": list(mapping.order_dram),
        },
    }


def _decode_entry(doc: dict) -> tuple[Mapping | None, float]:
    if not doc["feasible"]:
        return (None, float("inf"))
    m = doc["mapping"]
    mapping = Mapping(
        factors=tuple(tuple(int(f) for f in level) for level in m["factors"]),
        order_lb=tuple(m["order_lb"]),
        order_gb=tuple(m["order_gb"]),
        order_dram=tuple(m["order_dram"]),
    )
    return (mapping, float(doc["edp"]))


class DesignStore:
    """Content-addressed persistent store of inner-search results.

    `get`/`put` speak the engine's cache-entry type directly:
    `(Mapping | None, edp)` -- None marks a probed-and-infeasible layer
    (storing infeasibility matters: re-discovering it costs a full search).
    Tallies `hits`/`misses` for `CoDesignResult.stats`.
    """

    def __init__(self, directory: str):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)
        self.hits = 0
        self.misses = 0

    def _path(self, key: str) -> str:
        return os.path.join(self.directory, key[:2], key + ".json")

    def get(self, key: str) -> tuple[Mapping | None, float] | None:
        try:
            with open(self._path(key)) as f:
                doc = json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            self.misses += 1
            return None
        self.hits += 1
        return _decode_entry(doc)

    def put(self, key: str, entry: tuple[Mapping | None, float]) -> None:
        path = self._path(key)
        directory = os.path.dirname(path)
        os.makedirs(directory, exist_ok=True)
        # Atomic publish (the checkpoint/ idiom): write a unique temp file in
        # the destination directory, then rename over the final name --
        # readers never see a torn entry, concurrent same-key writers race
        # benignly (identical content-addressed bytes).
        fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(_encode_entry(entry), f)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def __len__(self) -> int:
        n = 0
        for _, _, files in os.walk(self.directory):
            n += sum(1 for f in files if f.endswith(".json"))
        return n

    def _entries(self) -> list[tuple[float, int, str]]:
        """Every stored entry as (mtime, size_bytes, path)."""
        out = []
        for root, _, files in os.walk(self.directory):
            for name in files:
                if not name.endswith(".json"):
                    continue
                path = os.path.join(root, name)
                try:
                    st = os.stat(path)
                except FileNotFoundError:  # concurrent pruner won the race
                    continue
                out.append((st.st_mtime, st.st_size, path))
        return out

    def stats(self) -> dict:
        """Entry count and byte footprint, total and per shard directory
        (the two-hex-char key-prefix fan-out)."""
        shards: dict[str, dict] = {}
        entries = bytes_total = 0
        for mtime, size, path in self._entries():
            shard = os.path.basename(os.path.dirname(path))
            s = shards.setdefault(shard, {"entries": 0, "bytes": 0})
            s["entries"] += 1
            s["bytes"] += size
            entries += 1
            bytes_total += size
        return {"entries": entries, "bytes": bytes_total,
                "shards": dict(sorted(shards.items()))}

    def prune(self, max_entries: int) -> int:
        """Evict oldest-first (by mtime, path-tiebroken) until at most
        `max_entries` entries remain; returns the number removed.

        Per-entry removal is a single `os.unlink`, atomic against the
        store's atomic-rename writers: a concurrent reader either sees a
        whole entry or a miss, never a torn one, and evicting is always
        result-preserving -- a missed key just re-runs its exact-replay
        search.  Concurrent pruners race benignly (unlink of an
        already-removed path is ignored)."""
        if not isinstance(max_entries, int) or isinstance(max_entries, bool) \
                or max_entries < 0:
            raise ValueError(
                f"max_entries must be an int >= 0, got {max_entries!r}")
        entries = sorted(self._entries())
        removed = 0
        for _, _, path in entries[:max(0, len(entries) - max_entries)]:
            try:
                os.unlink(path)
                removed += 1
            except FileNotFoundError:
                pass
        return removed
