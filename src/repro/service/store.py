"""Persistent design store: content-addressed (hw, layer) inner-search results.

The store is the cross-run sibling of `CodesignEngine`'s in-memory cache: an
entry records the outcome of ONE inner software-mapping search -- the best
mapping found (or infeasibility) and its true model EDP -- under a key that
hashes everything that determines that search bit-for-bit:

    design_key(hw, layer, sw_cfg, engine_cfg, probe_seed)

Probe seeds are already content-derived (`CodesignEngine.probe_seed`), so two
requests that probe the same hardware point under the same search config and
run seed share a key -- and a store hit is an *exact replay* of the search the
engine would run, not an approximation.  The scheduler prefills session
caches from the store before dispatching searches, so repeated or
overlapping workloads skip re-searching entirely.

Layout (one JSON file per entry, fanned out by key prefix):

    <dir>/ab/abcdef...1234.json

Writes reuse the `repro.checkpoint` atomic pattern -- serialize to a
temporary file in the destination directory, then `os.replace` -- so readers
never observe a torn entry and concurrent writers of the same key are safe
(last writer wins with identical bytes; keys are content-addressed).

Two cross-run *transfer* surfaces live alongside the exact store:

  `DesignStore.nearest`   approximate hits -- when an exact key misses, the
                          closest stored hardware point's mapping (same
                          layer, feature-space distance) can seed the new
                          search as a warm-start incumbent.  Never a replay:
                          callers re-evaluate the mapping on the target
                          hardware, so served EDPs stay exact.
  `TrialHistory`          per-workload-set append-only log of finished outer
                          trials (`history_key`), replayed as prior
                          observations into a warm-started outer GP.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
from typing import Sequence

import numpy as np

from repro.core.config import (EngineConfig, HWSearchConfig, SWSearchConfig)
from repro.timeloop.arch import HardwareConfig, hw_from_tuple
from repro.timeloop.mapping import Mapping
from repro.timeloop.workloads import ConvLayer

# Lazily built throwaway HardwareSpace for `DesignStore.nearest`'s feature
# distance (features() is a pure function of the config; the space instance
# only exists to reuse the one featurization definition).
_FEAT_SPACE = None


def _hw_features(hw: HardwareConfig) -> np.ndarray:
    from repro.core.hwspace import HardwareSpace

    global _FEAT_SPACE
    if _FEAT_SPACE is None:
        _FEAT_SPACE = HardwareSpace()
    return _FEAT_SPACE.features(hw)


def design_key(hw: HardwareConfig, layer: ConvLayer,
               sw_cfg: SWSearchConfig, engine_cfg: EngineConfig,
               probe_seed: int) -> str:
    """Stable content hash identifying one (hw, layer) inner search.

    Includes every field that can change the search's result: the hardware
    point, the layer, the full software search config, the engine fields the
    inner `bo_maximize` consumes (resolved backend, refit stride, batched
    protocol, pallas mode), and the probe's content-derived seed.  Engine
    fields that only move work around (strategy, use_cache, hw_*) are
    excluded -- strategies are pinned bit-identical to sequential."""
    eng = (engine_cfg.resolve_backend(), engine_cfg.gp_refit_every,
           engine_cfg.batched, engine_cfg.pallas_mode)
    data = repr((dataclasses.astuple(hw), dataclasses.astuple(layer),
                 dataclasses.astuple(sw_cfg), eng, int(probe_seed))).encode()
    return hashlib.blake2s(data, digest_size=16).hexdigest()


def _encode_entry(entry: tuple[Mapping | None, float]) -> dict:
    mapping, edp = entry
    if mapping is None:
        return {"feasible": False}
    return {
        "feasible": True,
        # float(edp) JSON round-trips exactly (repr serialization), so a
        # warm entry is bit-identical to the search that produced it.
        "edp": float(edp),
        "mapping": {
            "factors": [list(level) for level in mapping.factors],
            "order_lb": list(mapping.order_lb),
            "order_gb": list(mapping.order_gb),
            "order_dram": list(mapping.order_dram),
        },
    }


def _decode_entry(doc: dict) -> tuple[Mapping | None, float]:
    if not doc["feasible"]:
        return (None, float("inf"))
    m = doc["mapping"]
    mapping = Mapping(
        factors=tuple(tuple(int(f) for f in level) for level in m["factors"]),
        order_lb=tuple(m["order_lb"]),
        order_gb=tuple(m["order_gb"]),
        order_dram=tuple(m["order_dram"]),
    )
    return (mapping, float(doc["edp"]))


class DesignStore:
    """Content-addressed persistent store of inner-search results.

    `get`/`put` speak the engine's cache-entry type directly:
    `(Mapping | None, edp)` -- None marks a probed-and-infeasible layer
    (storing infeasibility matters: re-discovering it costs a full search).
    Tallies `hits`/`misses` for `CoDesignResult.stats`.
    """

    def __init__(self, directory: str):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)
        self.hits = 0
        self.misses = 0
        # (layer astuple) -> [(features, hw astuple, mapping, edp), ...]:
        # the approximate-hit index over stored *feasible* entries carrying
        # hw/layer metadata.  Built lazily on the first `nearest()` call and
        # kept current by `put`; None until then.
        self._nn: dict[tuple, list] | None = None

    def _path(self, key: str) -> str:
        return os.path.join(self.directory, key[:2], key + ".json")

    def get(self, key: str) -> tuple[Mapping | None, float] | None:
        path = self._path(key)
        try:
            with open(path) as f:
                doc = json.load(f)
            entry = _decode_entry(doc)
        except FileNotFoundError:
            self.misses += 1
            return None
        except (json.JSONDecodeError, KeyError, TypeError, ValueError):
            # Corrupt or schema-invalid entry (torn write survived a crash,
            # foreign file, old incompatible layout): a miss, and the file is
            # removed so it does not cost a failed parse on every future get
            # -- evicting is result-preserving (the search re-runs exactly).
            self.misses += 1
            try:
                os.unlink(path)
            except OSError:
                pass
            return None
        self.hits += 1
        return entry

    def put(self, key: str, entry: tuple[Mapping | None, float], *,
            hw: HardwareConfig | None = None,
            layer: ConvLayer | None = None) -> None:
        path = self._path(key)
        directory = os.path.dirname(path)
        os.makedirs(directory, exist_ok=True)
        doc = _encode_entry(entry)
        if hw is not None and layer is not None:
            # Optional provenance metadata: which (hw, layer) produced this
            # entry.  `_decode_entry` ignores it (exact gets are unchanged);
            # `nearest` indexes on it for approximate warm-start hits.
            doc["hw"] = list(dataclasses.astuple(hw))
            doc["layer"] = list(dataclasses.astuple(layer))
        # Atomic publish (the checkpoint/ idiom): write a unique temp file in
        # the destination directory, then rename over the final name --
        # readers never see a torn entry, concurrent same-key writers race
        # benignly (identical content-addressed bytes).
        fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(doc, f)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        if self._nn is not None and hw is not None and layer is not None \
                and entry[0] is not None:
            self._nn.setdefault(dataclasses.astuple(layer), []).append(
                (_hw_features(hw), dataclasses.astuple(hw),
                 entry[0], float(entry[1])))

    # --- approximate (near-identical hardware) lookup ----------------------------

    def _build_nn_index(self, max_scan: int) -> None:
        self._nn = {}
        scanned = 0
        paths = []
        for root, _, files in os.walk(self.directory):
            paths.extend(os.path.join(root, name) for name in files
                         if name.endswith(".json"))
        # Deterministic index regardless of directory-walk order; the scan
        # bound keeps index construction O(max_scan) on huge stores.
        for path in sorted(paths):
            if scanned >= max_scan:
                break
            scanned += 1
            try:
                with open(path) as f:
                    doc = json.load(f)
                if "hw" not in doc or "layer" not in doc:
                    continue  # pre-metadata entry: exact-only
                mapping, edp = _decode_entry(doc)
                if mapping is None:
                    continue  # infeasible entries never serve as warm starts
                hw_t = tuple(tuple(v) if isinstance(v, list) else v
                             for v in doc["hw"])
                layer_t = tuple(doc["layer"])
                feats = _hw_features(hw_from_tuple(hw_t))
            except (OSError, json.JSONDecodeError, KeyError, TypeError,
                    ValueError):
                continue
            self._nn.setdefault(layer_t, []).append(
                (feats, hw_t, mapping, float(edp)))

    def nearest(self, hw: HardwareConfig, layer: ConvLayer, *,
                max_scan: int = 4096
                ) -> tuple[HardwareConfig, Mapping, float] | None:
        """Closest stored feasible entry for this exact layer, by Euclidean
        distance in the hardware feature space (`HardwareSpace.features`):
        `(neighbor hw, its best mapping, its edp ON THE NEIGHBOR)` or None.

        This is the approximate sibling of `get`: the caller must treat the
        mapping as a warm-start *candidate* and re-evaluate it on the target
        hardware (the returned edp belongs to the neighbor's hardware, never
        the target's) -- results stay exact, only the search gets a head
        start.  The index scans at most `max_scan` entry files once, then
        stays current incrementally through `put`."""
        if self._nn is None:
            self._build_nn_index(max_scan)
        rows = self._nn.get(dataclasses.astuple(layer))
        if not rows:
            return None
        target = _hw_features(hw)
        d2 = np.array([float(np.sum((feats - target) ** 2))
                       for feats, _, _, _ in rows])
        feats, hw_t, mapping, edp = rows[int(np.argmin(d2))]
        return hw_from_tuple(hw_t), mapping, edp

    def __len__(self) -> int:
        n = 0
        for _, _, files in os.walk(self.directory):
            n += sum(1 for f in files if f.endswith(".json"))
        return n

    def _entries(self) -> list[tuple[float, int, str]]:
        """Every stored entry as (mtime, size_bytes, path)."""
        out = []
        for root, _, files in os.walk(self.directory):
            for name in files:
                if not name.endswith(".json"):
                    continue
                path = os.path.join(root, name)
                try:
                    st = os.stat(path)
                except FileNotFoundError:  # concurrent pruner won the race
                    continue
                out.append((st.st_mtime, st.st_size, path))
        return out

    def stats(self) -> dict:
        """Entry count and byte footprint, total and per shard directory
        (the two-hex-char key-prefix fan-out)."""
        shards: dict[str, dict] = {}
        entries = bytes_total = 0
        for mtime, size, path in self._entries():
            shard = os.path.basename(os.path.dirname(path))
            s = shards.setdefault(shard, {"entries": 0, "bytes": 0})
            s["entries"] += 1
            s["bytes"] += size
            entries += 1
            bytes_total += size
        return {"entries": entries, "bytes": bytes_total,
                "shards": dict(sorted(shards.items()))}

    def prune(self, max_entries: int) -> int:
        """Evict oldest-first (by mtime, path-tiebroken) until at most
        `max_entries` entries remain; returns the number removed.

        Per-entry removal is a single `os.unlink`, atomic against the
        store's atomic-rename writers: a concurrent reader either sees a
        whole entry or a miss, never a torn one, and evicting is always
        result-preserving -- a missed key just re-runs its exact-replay
        search.  Concurrent pruners race benignly (unlink of an
        already-removed path is ignored)."""
        if not isinstance(max_entries, int) or isinstance(max_entries, bool) \
                or max_entries < 0:
            raise ValueError(
                f"max_entries must be an int >= 0, got {max_entries!r}")
        # Sort on (mtime, path) exactly as documented: a plain sort of the
        # (mtime, size, path) triples would tiebreak equal mtimes on SIZE
        # before path, making eviction order depend on entry byte counts.
        entries = sorted(self._entries(), key=lambda e: (e[0], e[2]))
        removed = 0
        for _, _, path in entries[:max(0, len(entries) - max_entries)]:
            try:
                os.unlink(path)
                removed += 1
            except FileNotFoundError:
                pass
        self._nn = None  # pruned entries must leave the approximate index
        return removed


# --- cross-run trial history (outer-GP warm starts) ------------------------------


def history_key(layers: Sequence[ConvLayer], hw_cfg: HWSearchConfig,
                sw_cfg: SWSearchConfig, engine_cfg: EngineConfig) -> str:
    """Stable content hash identifying one *workload set's* outer-search
    problem: the layers, the hardware-space parameterization (num_pes), the
    inner-search config, and the engine fields that determine inner results
    (same set `design_key` hashes).

    Deliberately EXCLUDED: the run seed, the outer budget/acquisition knobs,
    prune/spec_k/elite_k/strategy, and every `warm_start*` field -- those
    change which hardware points get probed, not what a probe's
    `(features, utility, feasible)` row means, so cold runs under any of
    them write history that warm runs under any of them can consume."""
    eng = (engine_cfg.resolve_backend(), engine_cfg.gp_refit_every,
           engine_cfg.batched, engine_cfg.pallas_mode)
    data = repr((tuple(dataclasses.astuple(layer) for layer in layers),
                 int(hw_cfg.num_pes), dataclasses.astuple(sw_cfg),
                 eng)).encode()
    return hashlib.blake2s(data, digest_size=16).hexdigest()


class TrialHistory:
    """Append-only per-workload-set log of finished outer trials.

    One JSONL file per `history_key`, fanned out like the store
    (`<dir>/ab/ab...90.jsonl`); each line is one TRUE outer evaluation:

        {"hw": [astuple], "features": [11 floats],
         "utility": float | null, "feasible": bool}

    (bound-gate-censored trials are never logged -- their utilities are
    certificates, not measurements).  `append` publishes each row as ONE
    `os.write` on an `O_APPEND` descriptor, which POSIX keeps atomic for
    concurrent writers -- many service processes may log into one history
    directory; `load` skips any torn or foreign line instead of failing."""

    def __init__(self, directory: str):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)
        self.appended = 0

    def _path(self, hkey: str) -> str:
        return os.path.join(self.directory, hkey[:2], hkey + ".jsonl")

    def append(self, hkey: str, row: dict) -> None:
        path = self._path(hkey)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        line = (json.dumps(row, sort_keys=True) + "\n").encode()
        fd = os.open(path, os.O_WRONLY | os.O_CREAT | os.O_APPEND, 0o644)
        try:
            os.write(fd, line)
        finally:
            os.close(fd)
        self.appended += 1

    def load(self, hkey: str, max_rows: int = 0) -> list[dict]:
        """Rows for one history key, oldest first; `max_rows` > 0 keeps only
        the most recent.  Schema-invalid or torn lines are skipped (a
        concurrent writer's partial line must not poison every reader)."""
        try:
            with open(self._path(hkey), "rb") as f:
                data = f.read()
        except FileNotFoundError:
            return []
        rows: list[dict] = []
        for line in data.splitlines():
            if not line.strip():
                continue
            try:
                doc = json.loads(line)
                util = doc["utility"]
                rows.append({
                    "hw": tuple(tuple(v) if isinstance(v, list) else v
                                for v in doc["hw"]),
                    "features": [float(v) for v in doc["features"]],
                    "utility": None if util is None else float(util),
                    "feasible": bool(doc["feasible"]),
                })
            except (json.JSONDecodeError, KeyError, TypeError, ValueError):
                continue
        if max_rows and len(rows) > max_rows:
            rows = rows[-max_rows:]
        return rows

    def __len__(self) -> int:
        n = 0
        for _, _, files in os.walk(self.directory):
            n += sum(1 for f in files if f.endswith(".jsonl"))
        return n
