"""Cross-PR benchmark comparison gate (ROADMAP "perf trajectory tracking").

Compares the hot-path engine numbers in two `BENCH_codesign.json` records --
the previous commit's CI artifact vs the one just produced -- and fails (exit
1, with a GitHub `::error::` annotation) when the hot path regresses by more
than the threshold.  Improvements and per-layer details are emitted as
`::notice::` annotations.

    python -m benchmarks.compare_bench prev/BENCH_codesign.json \
        BENCH_codesign.json --threshold 0.20

The gate compares *speedup ratios* (scalar_s / engine_s, per layer under
`engine_speedup.layers`), not absolute seconds: both sides of a ratio are
measured in the same run on the same machine, so runner-to-runner wall-clock
variance (shared CI hardware spans CPU generations) cancels out, while a real
engine regression still shows up as a dropped ratio.

    speedup       NumPy batch engine vs scalar   (gating: geomean drop
                                                  >threshold -> fail)
    jax_speedup   JAX batch engine vs scalar     (annotating only: jit/dispatch
                                                  timings are noisier)

The multi-run nested-search paths are gated through `layer_batch_e2e` (the
layer-batched search vs the sequential-layer path) and `probe_fanout_e2e`
(the outer warmup's H-probe fan-out vs per-probe layer-batched), per backend
-- both sides of each ratio run the same engine on the same machine, so the
ratios are as robust as the hot-path ones:

    layer_batch_e2e.numpy_speedup    (gating)
    layer_batch_e2e.jax_speedup      (annotating only, like jax_speedup)
    probe_fanout_e2e.numpy_speedup   (gating)
    probe_fanout_e2e.jax_speedup     (annotating only, like jax_speedup)
    speculative_e2e.numpy_speedup    (gating; the record also carries the
                                      speculation cache hit-rate per backend)
    speculative_e2e.jax_speedup      (annotating only, like jax_speedup)
    prune_e2e.models.*.numpy_speedup (gating, one ratio per workload model:
                                      the bound-gated prune="safe" run vs
                                      speculative alone at paper-scale outer
                                      budgets; the record also carries the
                                      probes-gated count per backend)
    prune_e2e.models.*.jax_speedup   (annotating only, like jax_speedup)
    service_e2e.numpy_speedup        (gating: N fused concurrent co-design
                                      requests through the CodesignService vs
                                      the same N served sequentially; the
                                      record also carries requests/min and
                                      the warm-store replay time)
    service_e2e.jax_speedup          (annotating only, like jax_speedup)
    executor_e2e.numpy_speedup       (gating: the same mixed request batch
                                      through a process-executor service vs
                                      the single-process service; the record
                                      carries `cpus` -- the ratio is ~1x on a
                                      single-core runner and only shows real
                                      fan-out on multi-core CI hardware, but
                                      both sides of any one record share a
                                      machine so the cross-PR ratio holds)
    transfer_e2e.numpy_speedup       (gating: a repeated/near-identical
                                      request sequence against a warmed
                                      design store + trial history with
                                      hw.warm_start on, vs served cold; the
                                      record also carries the parity /
                                      never-worse booleans asserted
                                      in-benchmark and the store/warm-hit
                                      counts)
    transfer_e2e.jax_speedup         (annotating only, like jax_speedup)

A missing/invalid previous record is not an error -- first runs and artifact
expiry just skip the gate with a notice.  Records written before a metric
existed skip that metric the same way.
"""

from __future__ import annotations

import argparse
import json
import math
import sys
from pathlib import Path


def _load(path: str) -> dict | None:
    p = Path(path)
    if not p.is_file():
        return None
    try:
        return json.loads(p.read_text())
    except (OSError, json.JSONDecodeError):
        return None


def _speedups(record: dict, key: str) -> dict[str, float]:
    layers = (record.get("engine_speedup") or {}).get("layers") or {}
    return {
        name: float(r[key])
        for name, r in layers.items()
        if isinstance(r, dict) and isinstance(r.get(key), (int, float))
        and r[key] > 0
    }


def _section_speedups(record: dict, section: str, key: str) -> dict[str, float]:
    """A nested-search e2e record (`layer_batch_e2e` / `probe_fanout_e2e`)
    holds one ratio per backend (keyed by the workload model so the geomean
    machinery applies unchanged)."""
    lb = record.get(section) or {}
    if "models" in lb:
        # Multi-workload section (`prune_e2e`): one ratio per workload model.
        return {
            str(m): float(r[key])
            for m, r in (lb.get("models") or {}).items()
            if isinstance(r, dict) and isinstance(r.get(key), (int, float))
            and r[key] > 0
        }
    v = lb.get(key)
    if not isinstance(v, (int, float)) or v <= 0:
        return {}
    return {str(lb.get("model", "model")): float(v)}


def _geomean_ratio(old: dict[str, float], new: dict[str, float]) -> tuple[float | None, list[str]]:
    """Geomean of new/old per-layer speedup ratios over the shared layers
    (> 1 means the hot path got relatively faster, < 1 slower)."""
    shared = sorted(set(old) & set(new))
    if not shared:
        return None, []
    log_sum = 0.0
    details = []
    for name in shared:
        ratio = new[name] / old[name]
        log_sum += math.log(ratio)
        details.append(f"{name}: {old[name]:.2f}x -> {new[name]:.2f}x "
                       f"({(ratio - 1) * 100:+.1f}%)")
    return math.exp(log_sum / len(shared)), details


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("old", help="previous commit's BENCH_codesign.json")
    ap.add_argument("new", help="this run's BENCH_codesign.json")
    ap.add_argument("--threshold", type=float, default=0.20,
                    help="max allowed geomean hot-path speedup drop "
                         "(0.20 = -20%%)")
    args = ap.parse_args()

    old = _load(args.old)
    new = _load(args.new)
    if old is None:
        print(f"::notice::compare_bench: no previous record at {args.old}; "
              "skipping the regression gate (first run or expired artifact).")
        return 0
    if new is None:
        print(f"::error::compare_bench: current record {args.new} is missing "
              "or unreadable.")
        return 1

    failed = False
    for key, extract, gating in (
        ("speedup", _speedups, True),
        ("jax_speedup", _speedups, False),
        ("layer_batch.numpy_speedup", None, True),
        ("layer_batch.jax_speedup", None, False),
        ("probe_fanout.numpy_speedup", None, True),
        ("probe_fanout.jax_speedup", None, False),
        ("speculative.numpy_speedup", None, True),
        ("speculative.jax_speedup", None, False),
        ("prune.numpy_speedup", None, True),
        ("prune.jax_speedup", None, False),
        ("service.numpy_speedup", None, True),
        ("service.jax_speedup", None, False),
        ("executor.numpy_speedup", None, True),
        ("portfolio.numpy_speedup", None, True),
        ("portfolio.jax_speedup", None, False),
        ("transfer.numpy_speedup", None, True),
        ("transfer.jax_speedup", None, False),
    ):
        if extract is None:
            section, metric = key.split(".", 1)
            section = {"layer_batch": "layer_batch_e2e",
                       "probe_fanout": "probe_fanout_e2e",
                       "speculative": "speculative_e2e",
                       "prune": "prune_e2e",
                       "service": "service_e2e",
                       "executor": "executor_e2e",
                       "portfolio": "portfolio_e2e",
                       "transfer": "transfer_e2e"}[section]
            olds = _section_speedups(old, section, metric)
            news = _section_speedups(new, section, metric)
        else:
            olds, news = extract(old, key), extract(new, key)
        ratio, details = _geomean_ratio(olds, news)
        if ratio is None:
            print(f"::notice::compare_bench[{key}]: no shared layers to "
                  "compare (metric added/renamed?); skipping.")
            continue
        pct = (ratio - 1) * 100
        summary = (f"compare_bench[{key}]: geomean hot-path speedup "
                   f"{pct:+.1f}% vs previous ({'; '.join(details)})")
        if ratio < 1.0 - args.threshold:
            level = "error" if gating else "warning"
            print(f"::{level}::{summary} -- exceeds the "
                  f"{args.threshold:.0%} regression threshold.")
            failed = failed or gating
        else:
            print(f"::notice::{summary}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
