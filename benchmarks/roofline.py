"""Roofline table builder: reads artifacts/dryrun/*.json (produced by
`python -m repro.launch.dryrun --all [--multi-pod]`) and emits the
EXPERIMENTS.md tables."""

from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load(tag: str = "singlepod") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(ART, tag, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def table(tag: str = "singlepod") -> str:
    rows = [
        "| arch | shape | mesh | GiB/dev | fits | compute_s | memory_s | "
        "collective_s | bound | useful | MFU |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load(tag):
        if "skipped" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | - | - | "
                        f"SKIP: {r['skipped']} | - | - |")
            continue
        t = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['memory']['total_gib_per_dev']} | "
            f"{'Y' if r['memory']['fits_16g'] else 'N'} | "
            f"{t['compute_s']:.3e} | {t['memory_s']:.3e} | "
            f"{t['collective_s']:.3e} | {t['bound'].replace('_s','')} | "
            f"{r['useful_flops_ratio']:.2f} | {r['mfu_estimate']:.2%} |")
    return "\n".join(rows)


def summary(tag: str = "singlepod") -> dict:
    recs = [r for r in load(tag) if "skipped" not in r]
    if not recs:
        return {}
    worst = min(recs, key=lambda r: r["mfu_estimate"])
    coll = max(recs, key=lambda r: r["roofline"]["collective_s"]
               / max(r["roofline"]["step_time_s"], 1e-30))
    return {
        "cells": len(recs),
        "all_fit": all(r["memory"]["fits_16g"] for r in recs),
        "worst_mfu": (worst["arch"], worst["shape"], worst["mfu_estimate"]),
        "most_collective_bound": (coll["arch"], coll["shape"]),
    }


def run(quiet: bool = False):
    for tag in ("singlepod", "multipod"):
        recs = load(tag)
        if not recs:
            continue
        ok = [r for r in recs if "skipped" not in r]
        sk = [r for r in recs if "skipped" in r]
        if not quiet:
            print(f"roofline,{tag},cells={len(ok)},skipped={len(sk)},"
                  f"all_fit={all(r['memory']['fits_16g'] for r in ok)}")
    return summary()


if __name__ == "__main__":
    import sys
    tag = sys.argv[1] if len(sys.argv) > 1 else "singlepod"
    print(table(tag))
    print()
    print(summary(tag))
