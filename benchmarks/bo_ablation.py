"""Fig. 5b / Fig. 17: surrogate (GP vs RF) x acquisition (EI vs LCB) ablation.
Fig. 5c / Fig. 18: LCB lambda sweep.

Both on software-mapping optimization for ResNet-K4 (as in the paper)."""

from __future__ import annotations

import numpy as np

from repro.core import SoftwareSpace, bo_maximize
from repro.timeloop import PAPER_WORKLOADS, eyeriss_168


def run_surrogate_acq(n_trials: int = 100, seeds=(0, 1), layer="ResNet-K4"):
    space = SoftwareSpace(eyeriss_168(), PAPER_WORKLOADS[layer])
    out = {}
    for surrogate in ("gp_linear", "rf"):
        for acq in ("lcb", "ei"):
            finals = []
            for seed in seeds:
                r = bo_maximize(space, n_trials=n_trials,
                                n_warmup=min(30, n_trials // 4), pool_size=80,
                                acquisition=acq, lam=1.0,
                                surrogate=surrogate, seed=seed)
                finals.append(r.best_value)
            out[f"{surrogate}+{acq}"] = float(np.mean(finals))
    return out


def run_lambda_sweep(n_trials: int = 100, seeds=(0, 1), layer="ResNet-K4",
                     lams=(0.1, 0.5, 1.0, 2.0)):
    space = SoftwareSpace(eyeriss_168(), PAPER_WORKLOADS[layer])
    out = {}
    for lam in lams:
        finals = []
        for seed in seeds:
            r = bo_maximize(space, n_trials=n_trials,
                            n_warmup=min(30, n_trials // 4), pool_size=80,
                            acquisition="lcb", lam=lam,
                            surrogate="gp_linear", seed=seed)
            finals.append(r.best_value)
        out[lam] = float(np.mean(finals))
    return out


def run(n_trials: int = 100, seeds=(0, 1), quiet: bool = False):
    sa = run_surrogate_acq(n_trials, seeds)
    if not quiet:
        for k, v in sorted(sa.items(), key=lambda kv: -kv[1]):
            print(f"fig5b,{k},best_utility={v:.4f}")
    ls = run_lambda_sweep(n_trials, seeds)
    if not quiet:
        for lam, v in ls.items():
            print(f"fig5c,lambda={lam},best_utility={v:.4f}")
    return {"surrogate_acq": sa, "lambda": ls}


if __name__ == "__main__":
    run()
