"""Fig. 4 + Fig. 5a: nested hardware/software co-design vs the Eyeriss baseline.

Reports per-model EDP improvement over the hand-designed accelerator (Eyeriss
+ heuristic random mapper, Timeloop-style), the paper's headline table
(18.3% / 40.2% / 21.8% / 16.0% for ResNet / DQN / MLP / Transformer).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import codesign
from repro.core.bo import BOResult
from repro.core.hwspace import HardwareSpace
from repro.core.baselines import random_search
from repro.timeloop import MODEL_LAYERS, eyeriss_baseline_edp


def run_model(model: str, n_hw: int = 12, n_sw: int = 60, seeds=(0,),
              baseline_budget: int = 4000, hw_search: str = "bo"):
    layers = MODEL_LAYERS[model]
    num_pes = 256 if model == "transformer" else 168
    base = eyeriss_baseline_edp(layers, num_pes=num_pes, budget=baseline_budget)
    base_total = sum(base.values())
    bests, curves = [], []
    for seed in seeds:
        t0 = time.time()
        if hw_search == "bo":
            res = codesign(layers, num_pes=num_pes, n_hw_trials=n_hw,
                           n_sw_trials=n_sw, n_sw_warmup=min(20, n_sw // 3),
                           sw_pool=60, hw_pool=60, seed=seed)
            bests.append(res.best_model_edp)
            curves.append(res.hw_result.history)
        else:  # constrained random hardware search (paper's HW baseline)
            from repro.core.nested import optimize_software
            from repro.timeloop.model import evaluate as tl_eval

            def eval_hw(hw):
                total = 0.0
                for layer in layers:
                    r = optimize_software(hw, layer, n_trials=n_sw,
                                          n_warmup=min(20, n_sw // 3),
                                          pool_size=60, seed=seed + 1)
                    if r.best_point is None:
                        return None, False
                    total += tl_eval(hw, r.best_point, layer).edp
                eval_hw.best = min(getattr(eval_hw, "best", np.inf), total)
                return -float(np.log10(total)), True

            space = HardwareSpace(num_pes=num_pes, evaluate_fn=eval_hw)
            r = random_search(space, n_trials=n_hw, seed=seed)
            bests.append(getattr(eval_hw, "best", np.inf))
            curves.append(r.history)
    best = float(np.mean(bests))
    return {
        "model": model,
        "eyeriss_edp": base_total,
        "codesign_edp": best,
        "improvement_pct": (1 - best / base_total) * 100.0,
        "curve": np.mean(np.asarray(curves, dtype=np.float64), axis=0),
    }


def run(n_hw: int = 12, n_sw: int = 60, seeds=(0,), quiet: bool = False):
    out = {}
    for model in ("resnet", "dqn", "mlp", "transformer"):
        r = run_model(model, n_hw=n_hw, n_sw=n_sw, seeds=seeds)
        out[model] = r
        if not quiet:
            print(f"fig5a,{model},eyeriss={r['eyeriss_edp']:.3e},"
                  f"codesign={r['codesign_edp']:.3e},"
                  f"improvement={r['improvement_pct']:.1f}%")
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true",
                    help="paper-scale budgets (50 HW x 250 SW)")
    ap.add_argument("--hw-search", default="bo", choices=("bo", "random"))
    args = ap.parse_args()
    if args.paper:
        run(n_hw=50, n_sw=250, seeds=(0, 1, 2))
    else:
        run()
