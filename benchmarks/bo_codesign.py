"""Fig. 4 + Fig. 5a: nested hardware/software co-design vs the Eyeriss baseline.

Reports per-model EDP improvement over the hand-designed accelerator (Eyeriss
+ heuristic random mapper, Timeloop-style), the paper's headline table
(18.3% / 40.2% / 21.8% / 16.0% for ResNet / DQN / MLP / Transformer).

Also benchmarks the batched evaluation engine (`repro.timeloop.batch`) against
the scalar reference path on the co-design hot loop — per-trial candidate-pool
sampling + featurization + EDP scoring — and end-to-end on a reduced nested
co-design run.  `run(..., collect=dict)` fills a JSON-serializable record
(wall time, best log10 EDP per seed, speedups) that `benchmarks/run.py --json`
writes to BENCH_codesign.json so the perf trajectory is tracked across PRs.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import (CodesignConfig, CodesignEngine, EngineConfig,
                        HWSearchConfig, SWSearchConfig, optimize_software)
from repro.core.bo import BOResult
from repro.core.hwspace import HardwareSpace
from repro.core.swspace import SoftwareSpace
from repro.core.baselines import random_search
from repro.timeloop import MODEL_LAYERS, eyeriss_baseline_edp, eyeriss_168
from repro.timeloop import batch as tlb
from repro.timeloop import evaluate
from repro.timeloop.mapping import constrained_random_mapping, mapping_is_valid


def bench_config(model: str, n_hw: int, n_sw: int, seed: int = 0,
                 backend: str | None = None, gp_refit_every: int = 1,
                 batched: bool = True, strategy: str = "auto",
                 hw_warmup: int | None = None, spec_k: int = 4,
                 hw_gp_refit_every: int = 1) -> CodesignConfig:
    """The benchmark suite's reduced-budget `CodesignConfig` (pool 60, warmup
    n_sw//3 capped at 20 -- the pre-config kwarg bundle, as one object)."""
    num_pes = 256 if model == "transformer" else 168
    return CodesignConfig(
        sw=SWSearchConfig(n_trials=n_sw, n_warmup=min(20, n_sw // 3),
                          pool_size=60),
        hw=HWSearchConfig(n_trials=n_hw, pool_size=60, num_pes=num_pes,
                          spec_k=spec_k,
                          **({} if hw_warmup is None
                             else {"n_warmup": hw_warmup})),
        engine=EngineConfig(backend=backend, strategy=strategy,
                            gp_refit_every=gp_refit_every, batched=batched,
                            use_cache=batched,
                            hw_gp_refit_every=hw_gp_refit_every),
        seed=seed,
    )


def run_model(model: str, n_hw: int = 12, n_sw: int = 60, seeds=(0,),
              baseline_budget: int = 4000, hw_search: str = "bo",
              engine: str = "batched", backend: str | None = None,
              gp_refit_every: int = 1, config: CodesignConfig | None = None):
    from repro.core.swspace import default_backend

    backend = backend or default_backend()  # None -> $REPRO_BACKEND or numpy
    layers = MODEL_LAYERS[model]
    num_pes = 256 if model == "transformer" else 168
    if config is not None:
        backend = config.engine.resolve_backend()  # record what actually ran
        num_pes = config.hw.num_pes  # baseline at the SAME PE budget as the search
    base = eyeriss_baseline_edp(layers, num_pes=num_pes, budget=baseline_budget)
    base_total = sum(base.values())
    batched = engine == "batched"
    bests, curves, times = [], [], []
    for seed in seeds:
        t0 = time.time()
        if hw_search == "bo":
            cfg = (dataclasses.replace(config, seed=seed)
                   if config is not None else
                   bench_config(model, n_hw, n_sw, seed=seed, backend=backend,
                                gp_refit_every=gp_refit_every,
                                batched=batched))
            res = CodesignEngine(cfg).run(layers)
            bests.append(res.best_model_edp)
            curves.append(res.hw_result.history)
        else:  # constrained random hardware search (paper's HW baseline)
            from repro.timeloop.model import evaluate as tl_eval

            if config is not None:  # honor the config here too
                sw_cfg, eng_cfg = config.sw, config.engine
            else:
                sw_cfg = SWSearchConfig(n_trials=n_sw,
                                        n_warmup=min(20, n_sw // 3),
                                        pool_size=60)
                eng_cfg = EngineConfig(backend=backend, batched=batched)

            def eval_hw(hw):
                total = 0.0
                for layer in layers:
                    r = optimize_software(hw, layer, sw_cfg, seed=seed + 1,
                                          engine=eng_cfg)
                    if r.best_point is None:
                        return None, False
                    total += tl_eval(hw, r.best_point, layer).edp
                eval_hw.best = min(getattr(eval_hw, "best", np.inf), total)
                return -float(np.log10(total)), True

            space = HardwareSpace(num_pes=num_pes, evaluate_fn=eval_hw)
            r = random_search(space, n_trials=n_hw, seed=seed)
            bests.append(getattr(eval_hw, "best", np.inf))
            curves.append(r.history)
        times.append(time.time() - t0)
    best = float(np.mean(bests))
    return {
        "model": model,
        "eyeriss_edp": base_total,
        "codesign_edp": best,
        "improvement_pct": (1 - best / base_total) * 100.0,
        "curve": np.mean(np.asarray(curves, dtype=np.float64), axis=0),
        "wall_time_s": times,
        "best_log10_edp_per_seed": [float(np.log10(b)) for b in bests],
        "engine": engine,
        "backend": backend,
    }


def engine_speedup(layers=("ResNet-K2", "DQN-K1", "Transformer-K2"),
                   pool: int = 150, reps: int = 20, seed: int = 0) -> dict:
    """Hot-path microbenchmark mirroring exactly one BO acquisition trial:
    draw an input-valid pool, featurize it, evaluate the acquisition argmax
    (here: candidate 0 — the surrogate posterior is engine-independent and
    excluded).  Scalar reference vs the NumPy batch engine vs the JAX engine
    (`batch_jax`, jit-warmed before timing), per layer plus geomeans — both
    backends' hot-path timings land in BENCH_codesign.json."""
    from repro.timeloop import PAPER_WORKLOADS
    from repro.timeloop import batch_jax as jtlb

    hw = eyeriss_168()
    out: dict = {"pool": pool, "reps": reps, "layers": {}}
    speedups, speedups_jax = [], []
    for name in layers:
        layer = PAPER_WORKLOADS[name]
        space = SoftwareSpace(hw, layer)

        rng = np.random.default_rng(seed)
        t0 = time.perf_counter()
        for _ in range(reps):
            cands = []
            while len(cands) < pool:
                m = constrained_random_mapping(rng, hw, layer)
                if mapping_is_valid(m, hw, layer)[0]:
                    cands.append(m)
            np.stack([space.features(m) for m in cands])
            evaluate(hw, cands[0], layer)
        t_scalar = time.perf_counter() - t0

        rng = np.random.default_rng(seed)
        t0 = time.perf_counter()
        for _ in range(reps):
            mb = tlb.sample_valid_pool(rng, hw, layer, pool)
            tlb.features_batch(mb, hw, layer)
            evaluate(hw, mb[0], layer)
        t_batched = time.perf_counter() - t0

        # JAX engine: the fused device program covers features + EDP in one
        # dispatch; warm the jit cache outside the timed region.
        rng = np.random.default_rng(seed)
        warm = tlb.sample_valid_pool(rng, hw, layer, pool)
        jtlb.forward_device(hw, warm, layer)["features"].block_until_ready()
        rng = np.random.default_rng(seed)
        t0 = time.perf_counter()
        for _ in range(reps):
            mb = tlb.sample_valid_pool(rng, hw, layer, pool)
            jtlb.forward_device(hw, mb, layer)["features"].block_until_ready()
            evaluate(hw, mb[0], layer)  # same per-trial winner eval as above
        t_jax = time.perf_counter() - t0

        sp = t_scalar / t_batched
        sp_jax = t_scalar / t_jax
        speedups.append(sp)
        speedups_jax.append(sp_jax)
        out["layers"][name] = {
            "scalar_s": round(t_scalar, 4),
            "batched_s": round(t_batched, 4),
            "jax_s": round(t_jax, 4),
            "speedup": round(sp, 2),
            "jax_speedup": round(sp_jax, 2),
        }
    out["geomean_speedup"] = round(float(np.exp(np.mean(np.log(speedups)))), 2)
    out["geomean_jax_speedup"] = round(
        float(np.exp(np.mean(np.log(speedups_jax)))), 2)
    return out


def e2e_speedup(model: str = "dqn", n_hw: int = 4, n_sw: int = 40,
                seed: int = 0) -> dict:
    """End-to-end nested co-design at reduced budgets: NumPy / JAX batch
    engines + (hw, layer) cache vs the pre-engine scalar path.  (GP surrogate
    fits are identical on all sides, so this is bounded well below the raw
    engine speedup; the hot-path numbers are in `engine_speedup`.)"""
    layers = MODEL_LAYERS[model]
    out = {}
    for engine in ("scalar", "batched", "jax"):
        batched = engine != "scalar"
        backend = "jax" if engine == "jax" else "numpy"
        cfg = bench_config(model, n_hw, n_sw, seed=seed, backend=backend,
                           batched=batched)
        if engine == "jax":
            # Untimed warmup at the same pool/bucket sizes so one-time jit
            # compiles don't land inside the timed window (mirrors the
            # block_until_ready warmup in engine_speedup).
            CodesignEngine(dataclasses.replace(
                cfg, hw=dataclasses.replace(cfg.hw, n_trials=1))).run(layers)
        t0 = time.perf_counter()
        CodesignEngine(cfg).run(layers)
        out[f"{engine}_s"] = round(time.perf_counter() - t0, 3)
    out["speedup"] = round(out["scalar_s"] / out["batched_s"], 2)
    out["jax_speedup"] = round(out["scalar_s"] / out["jax_s"], 2)
    return out


def layer_batch_speedup(model: str = "resnet", n_hw: int = 4, n_sw: int = 60,
                        seed: int = 0, reps: int = 2) -> dict:
    """Layer-batched nested search vs the sequential-layer path (the PR-2
    baseline), per backend, on one multi-layer workload set.

    Both sides run the *same* search (same seeds, same per-layer RNG streams;
    see tests/test_layer_batch.py for the parity pin) -- the comparison
    isolates what the multi-run engine fuses: per-BO-round evaluation
    dispatches, surrogate refits, and acquisition scoring.  Each configuration
    is timed `reps` times interleaved and the per-side minimum is compared,
    which drops transient machine noise (shared CI hardware) rather than
    averaging it into the ratio.  JIT caches are warmed untimed."""
    layers = MODEL_LAYERS[model]
    out: dict = {"model": model, "n_hw": n_hw, "n_sw": n_sw, "reps": reps}
    for backend in ("numpy", "jax"):
        cfgs = {
            strat: bench_config(model, n_hw, n_sw, seed=seed, backend=backend,
                                strategy=strat)
            for strat in ("sequential", "layer_batched")
        }
        for cfg in cfgs.values():  # warm jit caches / one-time imports
            CodesignEngine(dataclasses.replace(
                cfg, hw=dataclasses.replace(cfg.hw, n_trials=1))).run(layers)
        times: dict[str, list[float]] = {s: [] for s in cfgs}
        for _ in range(reps):
            for strat, cfg in cfgs.items():
                t0 = time.perf_counter()
                CodesignEngine(cfg).run(layers)
                times[strat].append(time.perf_counter() - t0)
        seq_s, batch_s = min(times["sequential"]), min(times["layer_batched"])
        out[f"{backend}_sequential_s"] = round(seq_s, 3)
        out[f"{backend}_batched_s"] = round(batch_s, 3)
        out[f"{backend}_speedup"] = round(seq_s / batch_s, 2)
    return out


def probe_fanout_speedup(model: str = "resnet", n_hw: int = 4, n_sw: int = 60,
                         seed: int = 0, reps: int = 2) -> dict:
    """Probe-fanout nested search vs the layer-batched path, per backend --
    the ROADMAP "parallelize across hardware probes" capability the config
    API unlocked.

    The outer budget is all warmup (`hw.n_warmup = n_hw`), so every probe is
    an independent work item: `strategy="probe_fanout"` runs all H probes'
    H*L inner searches as ONE stacked `bo_maximize_many` (on jax each BO
    round is a single (H*L*B,)-row fused device program + one stacked GP
    fit), while `layer_batched` evaluates the probes one at a time (H
    dispatch-chains of L-run programs).  Both sides run the same searches with
    the same seeds -- parity is pinned in tests/test_config_api.py -- so the
    ratio isolates the fan-out's dispatch/fit amortization.  Timing protocol
    matches `layer_batch_speedup`: interleaved reps, per-side minimum, jit
    caches warmed untimed at full fan-out width."""
    layers = MODEL_LAYERS[model]
    out: dict = {"model": model, "n_hw": n_hw, "n_sw": n_sw, "reps": reps}
    for backend in ("numpy", "jax"):
        cfgs = {
            strat: bench_config(model, n_hw, n_sw, seed=seed, backend=backend,
                                strategy=strat, hw_warmup=n_hw)
            for strat in ("layer_batched", "probe_fanout")
        }
        for cfg in cfgs.values():
            # Full untimed warm run per side: the fan-out's (H*L*bucket,)-row
            # program and its stacked-GP bucket progression only exist at the
            # real probe count and trial budget, so any reduced warmup would
            # leave compiles inside the timed window.
            CodesignEngine(cfg).run(layers)
        times: dict[str, list[float]] = {s: [] for s in cfgs}
        for _ in range(reps):
            for strat, cfg in cfgs.items():
                t0 = time.perf_counter()
                CodesignEngine(cfg).run(layers)
                times[strat].append(time.perf_counter() - t0)
        base_s, fan_s = min(times["layer_batched"]), min(times["probe_fanout"])
        out[f"{backend}_layer_batched_s"] = round(base_s, 3)
        out[f"{backend}_fanout_s"] = round(fan_s, 3)
        out[f"{backend}_speedup"] = round(base_s / fan_s, 2)
    return out


def speculative_speedup(model: str = "resnet", n_hw: int = 11, n_sw: int = 40,
                        seed: int = 0, reps: int = 2, spec_k: int = 8,
                        hw_gp_refit_every: int = 8,
                        hw_warmup: int = 2) -> dict:
    """Speculative scored-trial fan-out vs the probe_fanout path -- the
    ROADMAP "parallelize the outer loop beyond warmup" capability.

    Both sides run with the same outer refit stride (`hw_gp_refit_every`), so
    the outer trajectory is identical (parity pinned in
    tests/test_speculative.py) and the ratio isolates what speculation adds:
    inside each frozen refit window, `speculative` evaluates the window's
    whole q-batch (the top-`spec_k` acquisition candidates) as ONE stacked
    k*L-run `bo_maximize_many` at the window's first trial, and the window's
    remaining trials consume pure cache hits -- per window, one wide stacked
    search replaces up to `stride` narrower ones.  `probe_fanout` evaluates
    the same probes one scored trial at a time.  The budget is mostly scored
    trials (`hw_warmup=2`) because that is the phase speculation covers; the
    per-backend cache hit-rate lands in the record (the gate's health signal:
    a silent 0 means speculation stopped predicting the outer loop).  Timing
    protocol matches `layer_batch_speedup`: interleaved reps, per-side
    minimum, jit caches warmed untimed by a full run."""
    layers = MODEL_LAYERS[model]
    out: dict = {"model": model, "n_hw": n_hw, "n_sw": n_sw, "reps": reps,
                 "spec_k": spec_k, "hw_gp_refit_every": hw_gp_refit_every}
    for backend in ("numpy", "jax"):
        cfgs = {
            strat: bench_config(model, n_hw, n_sw, seed=seed, backend=backend,
                                strategy=strat, hw_warmup=hw_warmup,
                                spec_k=spec_k,
                                hw_gp_refit_every=hw_gp_refit_every)
            for strat in ("probe_fanout", "speculative")
        }
        stats = {}
        for strat, cfg in cfgs.items():  # warm jit caches at full width
            stats[strat] = CodesignEngine(cfg).run(layers).stats
        times: dict[str, list[float]] = {s: [] for s in cfgs}
        for _ in range(reps):
            for strat, cfg in cfgs.items():
                t0 = time.perf_counter()
                CodesignEngine(cfg).run(layers)
                times[strat].append(time.perf_counter() - t0)
        base_s, spec_s = min(times["probe_fanout"]), min(times["speculative"])
        out[f"{backend}_probe_fanout_s"] = round(base_s, 3)
        out[f"{backend}_speculative_s"] = round(spec_s, 3)
        out[f"{backend}_speedup"] = round(base_s / spec_s, 2)
        out[f"{backend}_hit_rate"] = round(
            stats["speculative"]["spec_hit_rate"], 3)
        out[f"{backend}_spec_evaluated"] = stats["speculative"]["spec_evaluated"]
    return out


def prune_speedup(models=(("dqn", 40), ("mlp", 100)), n_hw: int = 50,
                  seed: int = 0, reps: int = 2, spec_k: int = 8,
                  hw_gp_refit_every: int = 8, hw_warmup: int = 2) -> dict:
    """Semi-decoupled bound gate (`prune="safe"`) vs `strategy="speculative"`
    alone, at paper-scale outer budgets (n_hw=50) -- the ROADMAP
    "semi-decoupled pruning" capability.

    The gate skips the whole inner mapping search of any scored probe whose
    provable EDP lower bound (`timeloop.bounds`) already exceeds the
    incumbent's true model EDP, observing a censored bound-derived utility
    instead; the incumbent is only updated by true evaluations, so the final
    design is unaffected in the safe mode.  The savings scale with how often
    the outer acquisition selects bound-dominated candidates (uninformed or
    stale posteriors inside frozen refit windows), so the record carries
    `*_probes_gated` -- the gate's health signal; a 0 means the bound never
    vetoed a selection and the two sides did identical work.

    Two records per workload and backend:

      *_speedup      fixed-budget wall-clock ratio, off/safe (both sides run
                     the identical trial budget; the safe side simply skips
                     provably-wasted searches)
      *_ttq_speedup  time-to-matched-quality ratio: time for each side to
                     first reach the worse of the two finals (guards against
                     a speedup bought with a quality loss)

    Timing protocol matches `speculative_speedup`: interleaved reps,
    per-side minimum, jit caches warmed untimed by one full run per side
    (large outer budgets compile GP buckets the small warmups never touch)."""
    out: dict = {"n_hw": n_hw, "reps": reps, "spec_k": spec_k,
                 "hw_gp_refit_every": hw_gp_refit_every, "models": {}}

    def traced(cfg, layers):
        marks: list[tuple[float, float]] = []
        t0 = time.perf_counter()
        r = CodesignEngine(cfg).run(
            layers, hw_callback=lambda t, res: marks.append(
                (time.perf_counter() - t0, res.best_value)))
        return r, marks, time.perf_counter() - t0

    def time_to(marks, target):
        for t, u in marks:
            if u >= target:
                return t
        return float("inf")

    for model, n_sw in models:
        layers = MODEL_LAYERS[model]
        rec: dict = {"n_sw": n_sw}
        for backend in ("numpy", "jax"):
            cfgs = {
                mode: dataclasses.replace(
                    base := bench_config(
                        model, n_hw, n_sw, seed=seed, backend=backend,
                        strategy="speculative", hw_warmup=hw_warmup,
                        spec_k=spec_k, hw_gp_refit_every=hw_gp_refit_every),
                    hw=dataclasses.replace(base.hw, prune=mode))
                for mode in ("off", "safe")
            }
            stats = {}
            for mode, cfg in cfgs.items():  # warm jit caches at full width
                stats[mode] = CodesignEngine(cfg).run(layers).stats
            times: dict[str, list[float]] = {m: [] for m in cfgs}
            ttq: dict[str, list[tuple]] = {m: [] for m in cfgs}
            finals: dict[str, float] = {}
            for _ in range(reps):
                for mode, cfg in cfgs.items():
                    r, marks, total = traced(cfg, layers)
                    times[mode].append(total)
                    ttq[mode].append(marks)
                    finals[mode] = r.hw_result.best_value
            target = min(finals["off"], finals["safe"])
            t_off = min(time_to(m, target) for m in ttq["off"])
            t_safe = min(time_to(m, target) for m in ttq["safe"])
            off_s, safe_s = min(times["off"]), min(times["safe"])
            rec[f"{backend}_off_s"] = round(off_s, 3)
            rec[f"{backend}_safe_s"] = round(safe_s, 3)
            rec[f"{backend}_speedup"] = round(off_s / safe_s, 2)
            rec[f"{backend}_ttq_speedup"] = (
                round(t_off / t_safe, 2) if t_safe > 0 else None)
            rec[f"{backend}_probes_gated"] = stats["safe"]["probes_gated"]
            rec[f"{backend}_gated_fraction"] = round(
                stats["safe"]["probes_gated"] / n_hw, 3)
            rec[f"{backend}_pruned_fraction"] = round(
                stats["safe"]["pruned_fraction"], 3)
        out["models"][model] = rec
    return out


def service_speedup(models=("dqn", "mlp", "dqn", "mlp", "dqn", "mlp"),
                    n_hw: int = 6, n_sw: int = 25, seed: int = 0,
                    reps: int = 2) -> dict:
    """Co-design-as-a-service throughput: N concurrent requests through the
    `CodesignService` (cross-request stacked dispatch fusion) vs the same N
    requests served one standalone `CodesignEngine.run` at a time -- the
    ISSUE-7 "requests/min" capability.

    Per-request results are bit-identical on both sides (parity asserted on
    every run and recorded), so the ratio isolates what the service fuses:
    each tick, every live session's pending inner searches run as ONE stacked
    `bo_maximize_many` instead of N separate dispatch chains.  `n_sw=25`
    keeps every stacked fit inside the Cholesky regime where fusion is exact.

    A second, untimed-cold / timed-warm pass exercises the persistent design
    store: the warm service run must perform ZERO inner searches (all (hw,
    layer) results replay from disk) -- `*_warm_store_misses` is the health
    signal and `*_warm_s` the replay latency.  Timing protocol matches
    `layer_batch_speedup`: interleaved reps, per-side minimum, jit caches
    warmed untimed by one full pass per side."""
    import shutil
    import tempfile

    from repro.core.config import ServiceConfig
    from repro.service import CodesignService, ServiceRequest

    out: dict = {"requests": list(models), "n_hw": n_hw, "n_sw": n_sw,
                 "reps": reps}
    for backend in ("numpy", "jax"):
        cfgs = [bench_config(model, n_hw, n_sw, seed=seed + i, backend=backend)
                for i, model in enumerate(models)]

        def sequential():
            return [CodesignEngine(c).run(MODEL_LAYERS[m])
                    for m, c in zip(models, cfgs)]

        def service(store_dir=None):
            svc = CodesignService(ServiceConfig(max_slots=len(models),
                                                store_dir=store_dir))
            rids = [svc.submit(ServiceRequest(layers=tuple(MODEL_LAYERS[m]),
                                              config=c))
                    for m, c in zip(models, cfgs)]
            responses = svc.run()
            return [responses[rid].result for rid in rids]

        seq_results = sequential()  # warm jit caches / one-time imports
        svc_results = service()
        parity = all(
            a.best_model_edp == b.best_model_edp and a.best_hw == b.best_hw
            for a, b in zip(seq_results, svc_results))
        times: dict[str, list[float]] = {"sequential": [], "service": []}
        for _ in range(reps):
            for name, fn in (("sequential", sequential),
                             ("service", service)):
                t0 = time.perf_counter()
                fn()
                times[name].append(time.perf_counter() - t0)
        seq_s, svc_s = min(times["sequential"]), min(times["service"])
        out[f"{backend}_sequential_s"] = round(seq_s, 3)
        out[f"{backend}_service_s"] = round(svc_s, 3)
        out[f"{backend}_speedup"] = round(seq_s / svc_s, 2)
        out[f"{backend}_rpm"] = round(len(models) / svc_s * 60.0, 1)
        out[f"{backend}_sequential_rpm"] = round(len(models) / seq_s * 60.0, 1)
        out[f"{backend}_parity"] = parity

        # warm-store replay: cold pass populates, warm pass must not search
        store_dir = tempfile.mkdtemp(prefix="bench_design_store_")
        try:
            service(store_dir=store_dir)  # cold, untimed
            t0 = time.perf_counter()
            warm_results = service(store_dir=store_dir)
            out[f"{backend}_warm_s"] = round(time.perf_counter() - t0, 3)
            out[f"{backend}_warm_store_misses"] = sum(
                r.stats["store_misses"] for r in warm_results)
        finally:
            shutil.rmtree(store_dir, ignore_errors=True)
    return out


def transfer_speedup(models=("mlp", "dqn", "mlp"), n_hw: int = 6,
                     n_sw: int = 25, seed: int = 0, reps: int = 2) -> dict:
    """Cross-run transfer: a repeated/near-identical request sequence served
    cold (no store, no history) vs served against a warmed design store +
    trial history with `hw.warm_start` on -- the ISSUE-10 capability.

    The warmed side replays every (hw, layer) search the cold pass already
    paid for from the store (warmup probes draw the same RNG stream, so they
    hit exactly), seeds its outer GP/classifier with the recorded trial
    history, and serves approximate (nearest stored hardware) warm starts on
    exact-key misses.  Contracts, per run:

      parity       (asserted) the untimed setup pass (store + history
                   attached, warm start OFF) is bit-identical to the cold
                   results -- logging and persistence alone change nothing;
      never_worse  (recorded) whether every warm-started request's final
                   model EDP is <= its cold counterpart's.  Priors reshape
                   the outer acquisition, and BO carries no per-seed
                   monotonicity guarantee at small budgets, so this is data,
                   not an invariant -- `tests/test_transfer.py` pins seeds
                   where it holds;
      the >=1.15x e2e bar (asserted, numpy): warm wall-clock vs cold -- or,
                   when a machine's I/O noise eats the ratio, a never-worse
                   run with a strictly better incumbent at the same budget
                   (`*_improved`) keeps the record honest.

    Timing protocol matches `layer_batch_speedup`: interleaved reps,
    per-side minimum, jit caches warmed untimed."""
    import shutil
    import tempfile

    from repro.core.config import ServiceConfig
    from repro.service import CodesignService, ServiceRequest

    out: dict = {"requests": list(models), "n_hw": n_hw, "n_sw": n_sw,
                 "reps": reps}
    for backend in ("numpy", "jax"):
        cold_cfgs = [bench_config(m, n_hw, n_sw, seed=seed + i,
                                  backend=backend)
                     for i, m in enumerate(models)]
        warm_cfgs = [dataclasses.replace(
                         c, hw=dataclasses.replace(c.hw, warm_start=True))
                     for c in cold_cfgs]

        def serve(cfgs, store_dir=None, history_dir=None):
            svc = CodesignService(ServiceConfig(max_slots=len(models),
                                                store_dir=store_dir,
                                                history_dir=history_dir))
            rids = [svc.submit(ServiceRequest(layers=tuple(MODEL_LAYERS[m]),
                                              config=c))
                    for m, c in zip(models, cfgs)]
            responses = svc.run()
            return [responses[rid].result for rid in rids]

        tmp = tempfile.mkdtemp(prefix="bench_transfer_")
        store_dir, hist_dir = tmp + "/store", tmp + "/history"
        try:
            cold_results = serve(cold_cfgs)  # warm jit caches, untimed
            # setup pass: populates store + history; with warm_start OFF it
            # must be bit-identical to cold (the exactness contract of the
            # persistence layer).
            setup_results = serve(cold_cfgs, store_dir, hist_dir)
            parity = all(
                a.best_model_edp == b.best_model_edp and a.best_hw == b.best_hw
                for a, b in zip(cold_results, setup_results))
            assert parity, "store/history attachment changed a cold result"
            warm_results = serve(warm_cfgs, store_dir, hist_dir)  # untimed
            never_worse = all(
                w.best_model_edp <= c.best_model_edp
                for w, c in zip(warm_results, cold_results))
            times: dict[str, list[float]] = {"cold": [], "warm": []}
            for _ in range(reps):
                for name, fn in (
                        ("cold", lambda: serve(cold_cfgs)),
                        ("warm", lambda: serve(warm_cfgs, store_dir,
                                               hist_dir))):
                    t0 = time.perf_counter()
                    fn()
                    times[name].append(time.perf_counter() - t0)
        finally:
            shutil.rmtree(tmp, ignore_errors=True)
        cold_s, warm_s = min(times["cold"]), min(times["warm"])
        out[f"{backend}_cold_s"] = round(cold_s, 3)
        out[f"{backend}_warm_s"] = round(warm_s, 3)
        out[f"{backend}_speedup"] = round(cold_s / warm_s, 2)
        out[f"{backend}_parity"] = parity
        out[f"{backend}_never_worse"] = never_worse
        out[f"{backend}_improved"] = sum(
            1 for w, c in zip(warm_results, cold_results)
            if w.best_model_edp < c.best_model_edp)
        out[f"{backend}_store_hits"] = sum(
            r.stats["store_hits"] for r in warm_results)
        out[f"{backend}_warm_hits"] = sum(
            r.stats["warm_hits"] for r in warm_results)
        out[f"{backend}_prior_rows"] = sum(
            r.stats["prior_rows"] for r in warm_results)
        if backend == "numpy":
            # the gated acceptance bar: a real e2e win, in time or quality
            assert out["numpy_speedup"] >= 1.15 or (
                never_worse and out["numpy_improved"] > 0), (
                f"transfer gave neither a >=1.15x e2e speedup "
                f"({out['numpy_speedup']}x) nor a better incumbent")
    return out


def executor_speedup(models=("dqn", "mlp", "dqn", "mlp", "dqn", "mlp"),
                     n_hw: int = 6, n_sw: int = 25, seed: int = 0,
                     reps: int = 2, n_workers: int = 4) -> dict:
    """Actor/learner fan-out: the 6-request mixed batch through a
    process-executor service (`n_workers` spawn-started workers pulling the
    per-tick fused dispatches, ticks overlapping) vs the same batch through
    the single-process inline-executor service -- `service_e2e`'s timed
    configuration.  Per-request results are bit-identical (parity asserted
    and recorded), so the ratio isolates placement: the learner keeps every
    outer GP/session state machine while workers run the stacked inner
    searches on other cores.

    The speedup scales with physical cores (`cpus` is recorded alongside:
    on a single-core host the workers timeslice one core and the ratio
    honestly sits at ~1x minus IPC overhead; at >= 4 cores the 4-worker
    fan-out is where the >= 1.5-2x target lives).  Numpy backend -- the
    gated configuration; worker pools start once, untimed, and persist
    across reps like every other warm-cache protocol here."""
    import os

    from repro.core.config import ServiceConfig
    from repro.parallel.executor import ProcessExecutor
    from repro.service import CodesignService, ServiceRequest

    cfgs = [bench_config(model, n_hw, n_sw, seed=seed + i, backend="numpy")
            for i, model in enumerate(models)]

    def serve(executor=None):
        svc = CodesignService(ServiceConfig(max_slots=len(models)),
                              executor=executor)
        rids = [svc.submit(ServiceRequest(layers=tuple(MODEL_LAYERS[m]),
                                          config=c))
                for m, c in zip(models, cfgs)]
        responses = svc.run()
        return [responses[rid].result for rid in rids]

    out: dict = {"requests": list(models), "n_hw": n_hw, "n_sw": n_sw,
                 "reps": reps, "n_workers": n_workers,
                 "cpus": os.cpu_count()}
    pool = ProcessExecutor(n_workers=n_workers)
    try:
        single_results = serve()  # warm jit caches / one-time imports
        pool_results = serve(pool)  # start + warm the worker pool, untimed
        parity = all(
            a.best_model_edp == b.best_model_edp and a.best_hw == b.best_hw
            for a, b in zip(single_results, pool_results))
        times: dict[str, list[float]] = {"single": [], "executor": []}
        for _ in range(reps):
            for name, fn in (("single", serve), ("executor",
                                                 lambda: serve(pool))):
                t0 = time.perf_counter()
                fn()
                times[name].append(time.perf_counter() - t0)
    finally:
        pool.close()
    single_s, exec_s = min(times["single"]), min(times["executor"])
    out["numpy_single_s"] = round(single_s, 3)
    out["numpy_executor_s"] = round(exec_s, 3)
    out["numpy_speedup"] = round(single_s / exec_s, 2)
    out["numpy_rpm"] = round(len(models) / exec_s * 60.0, 1)
    out["numpy_parity"] = parity
    return out


def portfolio_speedup(workloads=("smollm_360m", "qwen3_14b",
                                 "moonshot_v1_16b_a3b"),
                      n_hw: int = 4, n_sw: int = 25, seed: int = 0,
                      reps: int = 2) -> dict:
    """Portfolio co-design (one chip for a weighted workload mix) vs per-model
    specialist searches, on zoo-generated workload sets.

    Two results ship in one record.  (1) The specialist-vs-portfolio EDP
    *table*: each specialist chip (tuned for one model) and the uniform
    portfolio chip, scored on every member's workload (cross entries re-run
    the stacked inner search on the foreign chip with its content-derived
    seed).  `gap` condenses it: the geomean EDP penalty of running a
    specialist chip on the OTHER models vs their own specialists -- the
    cross-model generalization gap of "Rethinking Co-design" (2102.08619) --
    next to the portfolio chip's penalty, which should be smaller.  (2) The
    wall-clock ratio: M standalone specialist searches vs ONE portfolio
    search over the union stack at the same budgets (outer-loop fan-in: M*L
    layers share each trial's stacked dispatch and GP fit).  Timing protocol
    as everywhere: interleaved reps, per-side minimum, warm pass untimed.
    One-hot parity (`one_hot_parity`) re-runs the portfolio with weight 1 on
    the first member only and asserts it reproduces that specialist's chip
    exactly -- the bit-parity contract that pins the whole construction.
    Numpy numbers gate in CI; jax annotates."""
    from repro.core.nested import optimize_software_many
    from repro.workloads import (PortfolioConfig, portfolio_codesign,
                                 resolve_workload)

    member_layers = {m: tuple(resolve_workload(m)) for m in workloads}
    out: dict = {"workloads": list(workloads), "n_hw": n_hw, "n_sw": n_sw,
                 "reps": reps}

    def _total_edp(hw, layers, cfg) -> float:
        """Best-mapping model EDP of `layers` on a fixed chip, searched with
        the same content-derived seed the engine would use."""
        eng = CodesignEngine(cfg)
        results = optimize_software_many(hw, list(layers), cfg.sw,
                                         seed=eng.probe_seed(hw),
                                         engine=cfg.engine)
        total = 0.0
        for layer, r in zip(layers, results):
            if r.best_point is None:
                return float("inf")
            total += evaluate(hw, r.best_point, layer).edp
        return total

    for backend in ("numpy", "jax"):
        cfg = bench_config("zoo", n_hw, n_sw, seed=seed, backend=backend,
                           hw_warmup=2)

        def specialists():
            return {m: CodesignEngine(cfg).run(list(member_layers[m]))
                    for m in workloads}

        def portfolio():
            return portfolio_codesign(PortfolioConfig(workloads=workloads),
                                      cfg)

        spec = specialists()  # warm jit caches / one-time imports, untimed
        port = portfolio()
        times: dict[str, list[float]] = {"specialists": [], "portfolio": []}
        for _ in range(reps):
            for name, fn in (("specialists", specialists),
                             ("portfolio", portfolio)):
                t0 = time.perf_counter()
                fn()
                times[name].append(time.perf_counter() - t0)
        spec_s = min(times["specialists"])
        port_s = min(times["portfolio"])
        out[f"{backend}_specialists_s"] = round(spec_s, 3)
        out[f"{backend}_portfolio_s"] = round(port_s, 3)
        out[f"{backend}_speedup"] = round(spec_s / port_s, 2)

        if backend != "numpy":
            continue
        # --- specialist-vs-portfolio EDP table (numpy, computed once) ------
        port_edps = port.stats["portfolio_member_edps"]
        table: dict[str, dict[str, float]] = {}
        for m in workloads:
            row = {}
            for m2 in workloads:
                row[m2] = (spec[m].best_model_edp if m2 == m else
                           _total_edp(spec[m].best_hw, member_layers[m2],
                                      cfg))
            table[f"specialist:{m}"] = row
        table["portfolio"] = {m2: port_edps[m2] for m2 in workloads}
        out["table"] = {chip: {m: _finite(v) for m, v in row.items()}
                        for chip, row in table.items()}

        def geomean(ratios):
            ratios = [r for r in ratios]
            return float(np.exp(np.mean(np.log(ratios)))) if ratios else None

        cross = [table[f"specialist:{m}"][m2] / table[f"specialist:{m2}"][m2]
                 for m in workloads for m2 in workloads if m2 != m]
        port_pen = [table["portfolio"][m2] / table[f"specialist:{m2}"][m2]
                    for m2 in workloads]
        out["gap"] = {
            "specialist_cross_penalty": _finite(round(geomean(cross), 3)),
            "portfolio_penalty": _finite(round(geomean(port_pen), 3)),
        }
        # --- one-hot parity: the acceptance-contract bit-parity check ------
        hot = portfolio_codesign(
            PortfolioConfig(workloads=workloads,
                            weights=(1.0,) + (0.0,) * (len(workloads) - 1)),
            cfg)
        first = workloads[0]
        out["one_hot_parity"] = bool(
            hot.best_hw == spec[first].best_hw
            and hot.stats["portfolio_member_edps"][first]
            == spec[first].best_model_edp)
    return out


def run(n_hw: int = 12, n_sw: int = 60, seeds=(0,), quiet: bool = False,
        collect: dict | None = None, backend: str | None = None,
        gp_refit_every: int = 1, config: CodesignConfig | None = None):
    """Fig. 4/5a over the four seed models.  `config` (e.g. loaded from
    `benchmarks/run.py --config path.json`) overrides the per-model budget
    construction entirely -- only the seed is replaced per run."""
    out = {}
    for model in ("resnet", "dqn", "mlp", "transformer"):
        r = run_model(model, n_hw=n_hw, n_sw=n_sw, seeds=seeds, backend=backend,
                      gp_refit_every=gp_refit_every, config=config)
        out[model] = r
        if not quiet:
            print(f"fig5a,{model},eyeriss={r['eyeriss_edp']:.3e},"
                  f"codesign={r['codesign_edp']:.3e},"
                  f"improvement={r['improvement_pct']:.1f}%,"
                  f"time={sum(r['wall_time_s']):.1f}s")
        if collect is not None:
            collect.setdefault("codesign", {})[model] = {
                "eyeriss_edp": _finite(r["eyeriss_edp"]),
                "codesign_edp": _finite(r["codesign_edp"]),
                "improvement_pct": _finite(round(r["improvement_pct"], 2)),
                "wall_time_s": [round(t, 3) for t in r["wall_time_s"]],
                "best_log10_edp_per_seed": [
                    _finite(b) for b in r["best_log10_edp_per_seed"]
                ],
                "seeds": list(seeds),
                "backend": r["backend"],
            }
    return out


def _finite(x: float):
    """JSON-safe number: strict JSON has no Infinity/NaN token, so non-finite
    values (e.g. a seed with no feasible design) become null."""
    return float(x) if np.isfinite(x) else None


def print_speedups(eng: dict, e2e: dict, lb: dict | None = None,
                   pf: dict | None = None, spec: dict | None = None,
                   prune: dict | None = None,
                   svc: dict | None = None,
                   execu: dict | None = None,
                   portfolio: dict | None = None,
                   transfer: dict | None = None) -> None:
    """CSV lines for the engine/e2e speedup records (shared with run.py)."""
    for name, r in eng["layers"].items():
        print(f"engine,{name},scalar={r['scalar_s']}s,"
              f"batched={r['batched_s']}s,jax={r['jax_s']}s,"
              f"speedup={r['speedup']}x,jax_speedup={r['jax_speedup']}x")
    print(f"engine,geomean,speedup={eng['geomean_speedup']}x,"
          f"jax_speedup={eng['geomean_jax_speedup']}x")
    print(f"e2e,codesign,scalar={e2e['scalar_s']}s,"
          f"batched={e2e['batched_s']}s,jax={e2e['jax_s']}s,"
          f"speedup={e2e['speedup']}x,jax_speedup={e2e['jax_speedup']}x")
    if lb is not None:
        print(f"layer_batch,{lb['model']},"
              f"numpy_seq={lb['numpy_sequential_s']}s,"
              f"numpy_batched={lb['numpy_batched_s']}s,"
              f"numpy_speedup={lb['numpy_speedup']}x,"
              f"jax_seq={lb['jax_sequential_s']}s,"
              f"jax_batched={lb['jax_batched_s']}s,"
              f"jax_speedup={lb['jax_speedup']}x")
    if pf is not None:
        print(f"probe_fanout,{pf['model']},"
              f"numpy_base={pf['numpy_layer_batched_s']}s,"
              f"numpy_fanout={pf['numpy_fanout_s']}s,"
              f"numpy_speedup={pf['numpy_speedup']}x,"
              f"jax_base={pf['jax_layer_batched_s']}s,"
              f"jax_fanout={pf['jax_fanout_s']}s,"
              f"jax_speedup={pf['jax_speedup']}x")
    if spec is not None:
        print(f"speculative,{spec['model']},"
              f"numpy_base={spec['numpy_probe_fanout_s']}s,"
              f"numpy_spec={spec['numpy_speculative_s']}s,"
              f"numpy_speedup={spec['numpy_speedup']}x,"
              f"numpy_hit_rate={spec['numpy_hit_rate']},"
              f"jax_base={spec['jax_probe_fanout_s']}s,"
              f"jax_spec={spec['jax_speculative_s']}s,"
              f"jax_speedup={spec['jax_speedup']}x,"
              f"jax_hit_rate={spec['jax_hit_rate']}")
    if prune is not None:
        for model, r in prune["models"].items():
            print(f"prune,{model},"
                  f"numpy_off={r['numpy_off_s']}s,"
                  f"numpy_safe={r['numpy_safe_s']}s,"
                  f"numpy_speedup={r['numpy_speedup']}x,"
                  f"numpy_ttq_speedup={r['numpy_ttq_speedup']}x,"
                  f"numpy_gated={r['numpy_probes_gated']},"
                  f"jax_off={r['jax_off_s']}s,"
                  f"jax_safe={r['jax_safe_s']}s,"
                  f"jax_speedup={r['jax_speedup']}x,"
                  f"jax_gated={r['jax_probes_gated']}")
    if svc is not None:
        print(f"service,{len(svc['requests'])}req,"
              f"numpy_seq={svc['numpy_sequential_s']}s,"
              f"numpy_service={svc['numpy_service_s']}s,"
              f"numpy_speedup={svc['numpy_speedup']}x,"
              f"numpy_rpm={svc['numpy_rpm']},"
              f"numpy_parity={svc['numpy_parity']},"
              f"numpy_warm={svc['numpy_warm_s']}s,"
              f"numpy_warm_misses={svc['numpy_warm_store_misses']},"
              f"jax_seq={svc['jax_sequential_s']}s,"
              f"jax_service={svc['jax_service_s']}s,"
              f"jax_speedup={svc['jax_speedup']}x,"
              f"jax_rpm={svc['jax_rpm']},"
              f"jax_parity={svc['jax_parity']}")
    if execu is not None:
        print(f"executor,{len(execu['requests'])}req,"
              f"workers={execu['n_workers']},cpus={execu['cpus']},"
              f"numpy_single={execu['numpy_single_s']}s,"
              f"numpy_executor={execu['numpy_executor_s']}s,"
              f"numpy_speedup={execu['numpy_speedup']}x,"
              f"numpy_rpm={execu['numpy_rpm']},"
              f"numpy_parity={execu['numpy_parity']}")
    if portfolio is not None:
        print(f"portfolio,{len(portfolio['workloads'])}models,"
              f"numpy_specialists={portfolio['numpy_specialists_s']}s,"
              f"numpy_portfolio={portfolio['numpy_portfolio_s']}s,"
              f"numpy_speedup={portfolio['numpy_speedup']}x,"
              f"one_hot_parity={portfolio['one_hot_parity']},"
              f"spec_cross_penalty="
              f"{portfolio['gap']['specialist_cross_penalty']},"
              f"portfolio_penalty={portfolio['gap']['portfolio_penalty']},"
              f"jax_specialists={portfolio['jax_specialists_s']}s,"
              f"jax_portfolio={portfolio['jax_portfolio_s']}s,"
              f"jax_speedup={portfolio['jax_speedup']}x")
        for chip, row in portfolio["table"].items():
            cells = ",".join(f"{m}={v:.3e}" if v is not None else f"{m}=inf"
                             for m, v in row.items())
            print(f"portfolio_table,{chip},{cells}")
    if transfer is not None:
        print(f"transfer,{len(transfer['requests'])}req,"
              f"numpy_cold={transfer['numpy_cold_s']}s,"
              f"numpy_warm={transfer['numpy_warm_s']}s,"
              f"numpy_speedup={transfer['numpy_speedup']}x,"
              f"numpy_parity={transfer['numpy_parity']},"
              f"numpy_never_worse={transfer['numpy_never_worse']},"
              f"numpy_improved={transfer['numpy_improved']},"
              f"numpy_store_hits={transfer['numpy_store_hits']},"
              f"numpy_warm_hits={transfer['numpy_warm_hits']},"
              f"numpy_prior_rows={transfer['numpy_prior_rows']},"
              f"jax_cold={transfer['jax_cold_s']}s,"
              f"jax_warm={transfer['jax_warm_s']}s,"
              f"jax_speedup={transfer['jax_speedup']}x,"
              f"jax_parity={transfer['jax_parity']}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true",
                    help="paper-scale budgets (50 HW x 250 SW)")
    ap.add_argument("--hw-search", default="bo", choices=("bo", "random"))
    ap.add_argument("--speedup", action="store_true",
                    help="only run the batched-engine speedup benchmarks")
    ap.add_argument("--backend", default=None, choices=("numpy", "jax"),
                    help="inner evaluation engine for the co-design runs "
                         "(default: $REPRO_BACKEND or numpy)")
    ap.add_argument("--gp-refit-every", type=int, default=1,
                    help="inner-loop surrogate refit stride (GP amortization)")
    args = ap.parse_args()
    if args.speedup:
        # Reduced prune budgets here (the CI smoke's): the paper-scale
        # defaults belong to benchmarks/run.py's recorded section.
        print_speedups(engine_speedup(), e2e_speedup(), layer_batch_speedup(),
                       probe_fanout_speedup(), speculative_speedup(),
                       prune_speedup(models=(("dqn", 20), ("mlp", 25)),
                                     n_hw=16, reps=1),
                       service_speedup(reps=1),
                       portfolio=portfolio_speedup(reps=1),
                       transfer=transfer_speedup(reps=1))
    elif args.paper:
        run(n_hw=50, n_sw=250, seeds=(0, 1, 2), backend=args.backend,
            gp_refit_every=args.gp_refit_every)
    else:
        run(backend=args.backend, gp_refit_every=args.gp_refit_every)
