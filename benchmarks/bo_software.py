"""Fig. 3 / Fig. 16: software-mapping optimization, BO vs baselines.

For each neural model's layer(s), run our constrained-BO formulation against
constrained random search, relax-and-round BO, and the TVM-style GBT cost-model
search, and report best-so-far normalized reciprocal EDP curves.
Also (--feasibility / feasibility_report): the raw design-space feasibility
rate, reproducing the paper's "~22K samples for 150 feasible points" setting.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import SoftwareSpace, bo_maximize, random_search
from repro.core.baselines import relax_round_bo, tvm_style_search
from repro.timeloop import MODEL_LAYERS, eyeriss_168, eyeriss_256
from repro.timeloop.mapping import mapping_is_valid, random_mapping


def _hw_for(model: str):
    return eyeriss_256() if model == "transformer" else eyeriss_168()


def run_layer(model: str, layer_idx: int = 1, n_trials: int = 120,
              seeds=(0, 1), pool: int = 100):
    layers = MODEL_LAYERS[model]
    layer = layers[min(layer_idx, len(layers) - 1)]
    hw = _hw_for(model)
    space = SoftwareSpace(hw, layer)
    out = {}
    for method in ("bo", "random", "relax_round", "tvm_gbt"):
        curves = []
        t0 = time.time()
        for seed in seeds:
            if method == "bo":
                r = bo_maximize(space, n_trials=n_trials, n_warmup=min(30, n_trials // 4),
                                pool_size=pool, acquisition="lcb", lam=1.0,
                                surrogate="gp_linear", seed=seed)
            elif method == "random":
                r = random_search(space, n_trials=n_trials, seed=seed)
            elif method == "relax_round":
                r = relax_round_bo(space, n_trials=n_trials,
                                   n_warmup=min(30, n_trials // 4),
                                   pool_size=pool, seed=seed)
            else:
                r = tvm_style_search(space, n_trials=n_trials,
                                     n_warmup=min(30, n_trials // 4),
                                     pool_size=pool, seed=seed)
            curves.append(r.history)
        out[method] = {
            "curve": np.mean(np.asarray(curves, dtype=np.float64), axis=0),
            "best_log10_edp": float(-np.mean([c[-1] for c in curves])),
            "sec": time.time() - t0,
        }
    return layer.name, out


def feasibility_report(samples: int = 30_000, seed: int = 0):
    """Raw (naive) sampler feasibility across the paper workloads -- the
    paper's 'vast majority of the space is invalid' observation."""
    rows = []
    for model, layers in MODEL_LAYERS.items():
        hw = _hw_for(model)
        layer = layers[min(1, len(layers) - 1)]
        rng = np.random.default_rng(seed)
        ok = sum(mapping_is_valid(random_mapping(rng, hw, layer), hw, layer)[0]
                 for _ in range(samples))
        rows.append((layer.name, ok, samples, ok / samples))
    return rows


def run(n_trials: int = 120, seeds=(0, 1), quiet: bool = False):
    results = {}
    for model in ("resnet", "dqn", "mlp", "transformer"):
        name, out = run_layer(model, 1, n_trials=n_trials, seeds=seeds)
        results[name] = out
        if not quiet:
            row = " | ".join(f"{m}: {v['best_log10_edp']:.3f}" for m, v in out.items())
            best = min(out.items(), key=lambda kv: kv[1]["best_log10_edp"])[0]
            print(f"fig3,{name},{row},winner={best}")
    return results


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--trials", type=int, default=120)
    ap.add_argument("--paper", action="store_true", help="paper-scale budgets (250 trials)")
    ap.add_argument("--feasibility", action="store_true")
    args = ap.parse_args()
    if args.feasibility:
        for name, ok, n, rate in feasibility_report():
            print(f"feasibility,{name},{ok}/{n},{rate:.4%}")
    else:
        run(n_trials=250 if args.paper else args.trials,
            seeds=tuple(range(5)) if args.paper else (0, 1))
