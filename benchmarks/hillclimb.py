"""§Perf hillclimbing driver: evaluate named (sharding/config) variants of one
(arch x shape) cell via the dry-run analyzer and log hypothesis -> result.

    PYTHONPATH=src python -m benchmarks.hillclimb --cell phi3-medium-14b:train_4k
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json
import time


def evaluate_variant(arch, shape_name, *, rules=None, cfg_patch=None,
                     mesh_shape=None, extrapolate=True):
    import jax
    from repro.configs.base import SHAPES, get_config
    from repro.launch import dryrun as DR
    from repro.launch.mesh import make_production_mesh
    from repro.parallel.sharding import AxisRules

    cfg = get_config(arch)
    if cfg_patch:
        cfg = dataclasses.replace(cfg, **cfg_patch)
    shape = SHAPES[shape_name]
    if mesh_shape:
        mesh = jax.make_mesh(mesh_shape, ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
    else:
        mesh = make_production_mesh()
    rules = rules or AxisRules()
    lowered = DR.lower_cell(cfg, shape, mesh, rules)
    return DR.analyze(lowered, cfg, shape, mesh, rules, extrapolate=extrapolate)


def run_variants(arch, shape_name, variants, out_dir="artifacts/perf"):
    os.makedirs(out_dir, exist_ok=True)
    results = []
    for name, kwargs, hypothesis in variants:
        t0 = time.time()
        try:
            rec = evaluate_variant(arch, shape_name, **kwargs)
            t = rec["roofline"]
            row = {
                "variant": name, "hypothesis": hypothesis,
                "step_s": t["step_time_s"], "bound": t["bound"],
                "compute_s": t["compute_s"], "memory_s": t["memory_s"],
                "collective_s": t["collective_s"],
                "gib_per_dev": rec["memory"]["total_gib_per_dev"],
                "fits": rec["memory"]["fits_16g"],
                "mfu": rec["mfu_estimate"],
                "wall_s": round(time.time() - t0, 1),
            }
        except Exception as e:
            row = {"variant": name, "hypothesis": hypothesis,
                   "error": f"{type(e).__name__}: {str(e).splitlines()[0][:160]}",
                   "wall_s": round(time.time() - t0, 1)}
        results.append(row)
        print(json.dumps(row), flush=True)
    path = os.path.join(out_dir, f"{arch}__{shape_name}.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=1)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    args = ap.parse_args()
    arch, shape_name = args.cell.split(":")
    from repro.parallel.sharding import AxisRules
    variants = [
        ("baseline", {}, "paper-faithful default sharding (FSDP+TP16)"),
        ("seq_parallel", {"rules": AxisRules(seq="model")},
         "SP shards activations over model -> memory / collective down"),
        ("dp_heavy_64x4", {"mesh_shape": (64, 4)},
         "less TP when dims don't divide 16 -> fewer activation gathers"),
        ("no_fsdp", {"rules": AxisRules(fsdp=None)},
         "replicated params kill per-layer all-gathers (if they fit)"),
    ]
    run_variants(arch, shape_name, variants)
