"""Benchmark entry point: one section per paper table/figure plus the roofline
summary.  Prints `name,metric,...` CSV lines.

    PYTHONPATH=src python -m benchmarks.run            # reduced budgets
    PYTHONPATH=src python -m benchmarks.run --paper    # paper-scale budgets
    PYTHONPATH=src python -m benchmarks.run --json     # also write BENCH_codesign.json

`--json` records the co-design section's wall time and best log10 EDP per seed,
plus the batched-engine speedup over the scalar path, to BENCH_codesign.json so
the perf trajectory is tracked across PRs.
"""

from __future__ import annotations

import argparse
import json
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_codesign.json (wall time, best log10 EDP "
                         "per seed, engine speedups)")
    ap.add_argument("--backend", default=None, choices=("numpy", "jax"),
                    help="batched evaluation engine for the co-design section "
                         "(default: $REPRO_BACKEND or numpy; the speedup "
                         "section always times both)")
    ap.add_argument("--gp-refit-every", type=int, default=1,
                    help="inner-loop surrogate refit stride (GP amortization "
                         "knob, threaded to codesign)")
    ap.add_argument("--config", default=None, metavar="PATH",
                    help="JSON CodesignConfig (CodesignConfig.from_dict) for "
                         "the co-design section; overrides the budget/backend "
                         "flags for that section")
    args, _ = ap.parse_known_args()

    from repro.core import CodesignConfig
    from repro.core.swspace import default_backend

    config = None
    if args.config is not None:
        with open(args.config) as f:
            config = CodesignConfig.from_dict(json.load(f))

    backend = args.backend or default_backend()
    if config is not None:
        backend = config.engine.resolve_backend()

    from benchmarks import bo_ablation, bo_codesign, bo_software, roofline

    t0 = time.time()
    collect: dict | None = {} if args.json else None

    print("# Fig. 3 -- software-mapping optimization (best log10 EDP, lower wins)")
    bo_software.run(n_trials=250 if args.paper else 100,
                    seeds=tuple(range(3)) if args.paper else (0, 1))

    print("# feasibility -- raw design-space validity rate (paper: ~0.7%)")
    for name, ok, n, rate in bo_software.feasibility_report(
            samples=30_000 if args.paper else 8_000):
        print(f"feasibility,{name},{ok}/{n},{rate:.4%}")

    print(f"# Fig. 4 / 5a -- HW/SW co-design vs Eyeriss (backend={backend})")
    if args.paper:
        bo_codesign.run(n_hw=50, n_sw=250, seeds=(0, 1, 2), collect=collect,
                        backend=backend, gp_refit_every=args.gp_refit_every,
                        config=config)
    else:
        bo_codesign.run(n_hw=12, n_sw=60, seeds=(0,), collect=collect,
                        backend=backend, gp_refit_every=args.gp_refit_every,
                        config=config)

    print("# engines -- hot-path + end-to-end speedups (numpy + jax) vs scalar")
    eng = bo_codesign.engine_speedup()
    e2e = bo_codesign.e2e_speedup()
    print("# layer-batched nested search vs sequential layers (per backend)")
    lbe = bo_codesign.layer_batch_speedup()
    print("# probe-fanout warmup vs per-probe layer-batched (per backend)")
    pfe = bo_codesign.probe_fanout_speedup()
    print("# speculative scored-trial fan-out vs probe_fanout (per backend)")
    spec = bo_codesign.speculative_speedup()
    print("# bound-gated pruning (prune=safe) vs speculative alone "
          "(paper-scale outer budget, per backend)")
    prune = bo_codesign.prune_speedup()
    print("# co-design service -- fused concurrent requests vs sequential "
          "standalone (per backend)")
    svc = bo_codesign.service_speedup()
    print("# process executor -- multiprocess fan-out vs single-process "
          "service (numpy; speedup scales with cores)")
    execu = bo_codesign.executor_speedup()
    print("# workload portfolio -- one chip for a weighted zoo mix vs "
          "per-model specialists (wall + cross-model EDP table)")
    pfo = bo_codesign.portfolio_speedup()
    print("# cross-run transfer -- warmed store + trial history with "
          "hw.warm_start on vs served cold (per backend)")
    xfer = bo_codesign.transfer_speedup()
    bo_codesign.print_speedups(eng, e2e, lbe, pfe, spec, prune, svc, execu,
                               portfolio=pfo, transfer=xfer)

    print("# Fig. 5b/5c -- surrogate/acquisition + lambda ablations")
    bo_ablation.run(n_trials=250 if args.paper else 80,
                    seeds=(0, 1, 2) if args.paper else (0, 1))

    print("# Roofline -- dry-run derived terms (see EXPERIMENTS.md for tables)")
    s = roofline.run()
    if s:
        print(f"roofline,summary,{s}")

    total = time.time() - t0
    if collect is not None:
        collect["engine_speedup"] = eng
        collect["e2e_speedup"] = e2e
        collect["layer_batch_e2e"] = lbe
        collect["probe_fanout_e2e"] = pfe
        collect["speculative_e2e"] = spec
        collect["prune_e2e"] = prune
        collect["service_e2e"] = svc
        collect["executor_e2e"] = execu
        collect["portfolio_e2e"] = pfo
        collect["transfer_e2e"] = xfer
        collect["backend"] = backend
        collect["paper_budgets"] = bool(args.paper)
        collect["total_s"] = round(total, 1)
        with open("BENCH_codesign.json", "w") as f:
            json.dump(collect, f, indent=2, sort_keys=True)
        print("# wrote BENCH_codesign.json")

    print(f"# total {total:.0f}s")


if __name__ == "__main__":
    main()
