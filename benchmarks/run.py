"""Benchmark entry point: one section per paper table/figure plus the roofline
summary.  Prints `name,metric,...` CSV lines.

    PYTHONPATH=src python -m benchmarks.run            # reduced budgets
    PYTHONPATH=src python -m benchmarks.run --paper    # paper-scale budgets
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true")
    args, _ = ap.parse_known_args()

    from benchmarks import bo_ablation, bo_codesign, bo_software, roofline

    t0 = time.time()
    print("# Fig. 3 -- software-mapping optimization (best log10 EDP, lower wins)")
    bo_software.run(n_trials=250 if args.paper else 100,
                    seeds=tuple(range(3)) if args.paper else (0, 1))

    print("# feasibility -- raw design-space validity rate (paper: ~0.7%)")
    for name, ok, n, rate in bo_software.feasibility_report(
            samples=30_000 if args.paper else 8_000):
        print(f"feasibility,{name},{ok}/{n},{rate:.4%}")

    print("# Fig. 4 / 5a -- HW/SW co-design vs Eyeriss")
    if args.paper:
        bo_codesign.run(n_hw=50, n_sw=250, seeds=(0, 1, 2))
    else:
        bo_codesign.run(n_hw=12, n_sw=60, seeds=(0,))

    print("# Fig. 5b/5c -- surrogate/acquisition + lambda ablations")
    bo_ablation.run(n_trials=250 if args.paper else 80,
                    seeds=(0, 1, 2) if args.paper else (0, 1))

    print("# Roofline -- dry-run derived terms (see EXPERIMENTS.md for tables)")
    s = roofline.run()
    if s:
        print(f"roofline,summary,{s}")

    print(f"# total {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
